//! `mase` — command-line driver for the MASE-RS dataflow compiler.
//!
//! Flag parsing is the typed [`mase::cli`] layer: every subcommand is a
//! [`Subcommand`] variant matched exhaustively below, every shared flag
//! is decoded once (strictly — malformed values are errors, not silent
//! defaults) into [`CommonArgs`], and `--fmt/--bits/--frac` resolve to
//! the same `FormatSpec` the `.mxa` packed-weight artifacts carry.
//!
//! Subcommands:
//!   pretrain  --all | --model M [--task T] [--steps N]
//!   profile   --model M [--task T]
//!   search    --model M [--task T] [--fmt F] [--algorithm A] [--trials N]
//!   sweep     [--models M,..] [--tasks T,..|all] [--fmts F,..] [--cache FILE]
//!   emit      --model M [--task T] [--out DIR]
//!   e2e       --model M [--task T] [--trials N] [--out DIR]
//!   ir        --model M            (print the MASE IR)
//!   check     [--sv PATH] [--model M] [--fmt F] [--bits N] [--chan W]
//!   pack      --model M [--fmt F] [--bits N] [--out FILE.json|FILE.mxa]
//!             (.mxa = content-addressed packed-weight artifact; load it
//!              back with --weights for a zero-repack warm start)
//!   formats   [--model llama-sim]  (Table 1-style format comparison)
//!   generate  [--model toy-lm] [--tokens N] [--prompt-len N] [--seqs N] [--fmt F]
//!             (KV-cached greedy decode on the CPU backend)
//!   serve     [--model toy-lm] [--fmt F] [--port N] [--lanes N] [--queue-cap N]
//!             (HTTP inference service with continuous batching, CPU backend)
//!   trace     [--model M] [--fmt F] [--bits N] [--chan W] [--out FILE]
//!             [--trace-format chrome|jsonl] | --run e2e|sweep|generate ...
//!             (PR 8 observability: simulator timelines / flow traces)
//!
//! `search`, `e2e`, `emit`, `sweep` and `generate` additionally accept
//! `--trace [FILE]` (+ `--trace-format jsonl|chrome`) to record and
//! export the deterministic trace/metrics stream, and — together with
//! `serve` — `--weights FILE.mxa` to serve pre-packed weight tensors on
//! the CPU backend with zero re-quantize and zero re-pack.

use anyhow::{anyhow, Result};
use mase::cli::{flag_usize, CommonArgs, Subcommand};
use mase::coordinator::{cpu_backend_for, pretrain, PretrainConfig, Session};
use mase::formats::FormatKind;
use mase::runtime::{BackendKind, CpuBackend, ExecBackend};
use mase::util::cli::Args;
use std::path::{Path, PathBuf};

fn main() {
    let args = Args::from_env();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(args: &Args) -> Result<()> {
    let c = CommonArgs::parse(args)?;
    let dir = c.artifacts.clone();
    let open = || Session::open_for(&dir, c.backend);
    match c.sub {
        Subcommand::Help => println!("{}", HELP),
        // Packing and static analysis are artifact-free: a synthetic
        // model spec stands in when no manifest is present.
        Subcommand::Pack => cmd_pack(&c, args, &dir)?,
        Subcommand::Check => cmd_check(&c, args, &dir)?,
        Subcommand::Trace => match args.get("run") {
            // default mode: artifact-free simulator tracing, like `check`
            None => cmd_trace(&c, args, &dir)?,
            // delegate: `mase trace --run sweep ...` == `mase sweep --trace ...`
            Some(mode @ ("e2e" | "sweep" | "generate")) => {
                let mut fwd = args.clone();
                fwd.subcommand = Some(mode.to_string());
                fwd.flags.remove("run");
                fwd.flags.entry("trace".to_string()).or_insert_with(|| "true".to_string());
                return run(&fwd);
            }
            Some(other) => return Err(anyhow!("--run must be e2e|sweep|generate, got '{other}'")),
        },
        Subcommand::Pretrain => {
            anyhow::ensure!(
                c.backend == BackendKind::Pjrt,
                "pretraining drives the PJRT `train` artifact; rerun without --backend cpu \
                 (the cpu backend evaluates cached or freshly-initialized weights instead)"
            );
            let session = open()?;
            let cfg =
                PretrainConfig { steps: flag_usize(args, "steps", 220)?, ..Default::default() };
            if args.has("all") {
                pretrain::pretrain_all(&session, &cfg)?;
            } else {
                let model = c.require_model()?;
                let meta = session.manifest.model(model)?.clone();
                let task = if meta.kind == "lm" { None } else { Some(c.task) };
                pretrain::pretrain(&session, &meta, task, &cfg)?;
            }
            println!("pretraining done; weights in {}", dir.join("weights").display());
        }
        Subcommand::Profile => {
            let session = open()?;
            let model = c.require_model()?;
            let meta = session.manifest.model(model)?.clone();
            let w = pretrain::pretrain(&session, &meta, Some(c.task), &Default::default())?;
            let batches = mase::data::batches(c.task, 1, 2, meta.batch, meta.seq_len);
            let p = match c.backend {
                BackendKind::Pjrt => {
                    mase::passes::profile_model(&session.pjrt_backend()?, &meta, &w, &batches)?
                }
                BackendKind::Cpu => {
                    mase::passes::profile_model(&CpuBackend::new(), &meta, &w, &batches)?
                }
            };
            let mut t = mase::util::Table::new(vec!["qtensor", "variance", "absmax", "absmean"]);
            for i in 0..p.names.len() {
                t.row(vec![
                    p.names[i].clone(),
                    format!("{:.4e}", p.variance[i]),
                    format!("{:.4}", p.absmax[i]),
                    format!("{:.4}", p.absmean[i]),
                ]);
            }
            println!("{}", t.render());
            println!("variance spread (Fig 1a): {:.1}x", p.variance_spread());
        }
        Subcommand::Search | Subcommand::E2e | Subcommand::Emit => {
            let session = open()?;
            let model = c.require_model()?;
            let emit_dir = if matches!(c.sub, Subcommand::E2e | Subcommand::Emit)
                || c.out.is_some()
            {
                Some(
                    c.out
                        .as_ref()
                        .map(PathBuf::from)
                        .unwrap_or_else(|| dir.join("designs").join(model)),
                )
            } else {
                None
            };
            let cfg = c.flow_config(model, emit_dir.clone());
            let report = mase::coordinator::run_flow(&session, &cfg)?;
            let best = &report.outcome.best_eval;
            println!("model: {model}  task: {}  format: {}", c.task.name(), c.fmt.name());
            println!("fp32 accuracy:       {:.4}", report.fp32_accuracy);
            println!(
                "int8 baseline:       acc {:.4}, area-eff {:.3e}",
                report.int8_baseline.accuracy,
                report.int8_baseline.design.area_efficiency()
            );
            println!(
                "best {}: acc {:.4} (Δ {:+.4}), avg bits {:.2}, area-eff {:.3e} ({:.2}x int8), θ {:.0}/s, area {:.0} LUT",
                c.fmt.name(),
                best.accuracy,
                best.accuracy - report.fp32_accuracy,
                best.avg_bits,
                best.design.area_efficiency(),
                best.design.area_efficiency() / report.int8_baseline.design.area_efficiency(),
                best.design.throughput,
                best.design.area_luts,
            );
            if let Some(d) = emit_dir {
                println!(
                    "emitted {} SV files / {} lines to {}",
                    report.emitted_files,
                    report.emitted_lines,
                    d.display()
                );
            }
            let cs = &report.outcome.cache;
            println!(
                "eval cache: {} evaluations paid, {} served memoized ({:.0}% hit rate){}",
                cs.misses,
                cs.hits,
                cs.hit_rate() * 100.0,
                match &c.cache {
                    Some(p) => format!(", {} entries persisted to {}", cs.entries, p.display()),
                    None => String::new(),
                }
            );
            println!("\npass timing (Table 4):\n{}", report.pass_manager.report());
            finish_trace(&c, &report.trace)?;
        }
        Subcommand::Sweep => {
            let session = open()?;
            let cfg = c.sweep_config();
            let report = mase::coordinator::run_sweep(&session, &cfg)?;
            if let Some(note) = &report.load_note {
                println!("eval cache: {note}");
            }
            let mut t = mase::util::Table::new(vec![
                "model", "task", "fmt", "mode", "acc", "avg_bits", "evals", "hits", "hit%",
            ]);
            for row in &report.rows {
                t.row(vec![
                    row.item.model.clone(),
                    row.item.task.name().to_string(),
                    row.item.fmt.name().to_string(),
                    row.cell.mode.clone(),
                    format!("{:.3}", row.cell.accuracy),
                    format!("{:.2}", row.cell.avg_bits),
                    row.cache.misses.to_string(),
                    row.cache.hits.to_string(),
                    format!("{:.0}", row.cache.hit_rate() * 100.0),
                ]);
            }
            println!("{}", t.render());
            println!(
                "cache: {} entries loaded, {} stored, {} evaluations paid, {} memoized ({:.0}% hit rate)",
                report.loaded_entries,
                report.saved_entries,
                report.totals.misses,
                report.totals.hits,
                report.hit_rate() * 100.0,
            );
            match &cfg.cache_path {
                Some(p) => println!(
                    "flushed to {} — a re-run of this sweep performs zero re-simulations",
                    p.display()
                ),
                None => {
                    println!("(in-memory cache only; pass --cache FILE to persist across runs)")
                }
            }
            finish_trace(&c, &report.trace)?;
        }
        Subcommand::Ir => {
            let session = open()?;
            let model = c.require_model()?;
            let meta = session.manifest.model(model)?;
            let g = mase::frontend::build_graph(meta);
            println!("{}", mase::ir::print_graph(&g));
            println!("// DAG size: {} ops", g.dag_size());
        }
        Subcommand::Formats => {
            let session = open()?;
            match c.backend {
                BackendKind::Pjrt => cmd_formats(&session, &c, session.pjrt_backend()?)?,
                BackendKind::Cpu => cmd_formats(&session, &c, CpuBackend::new())?,
            }
        }
        Subcommand::Generate => {
            let session = open()?;
            match c.backend {
                BackendKind::Pjrt => {
                    anyhow::ensure!(
                        c.weights.is_none(),
                        "--weights is a packed-CPU-backend feature: the PJRT backend feeds raw \
                         f32 weights to the device and cannot serve a .mxa artifact \
                         (use --backend cpu)"
                    );
                    cmd_generate(&session, &c, args, session.pjrt_backend()?)?
                }
                BackendKind::Cpu => {
                    cmd_generate(&session, &c, args, cpu_backend_for(c.weights.as_deref())?)?
                }
            }
        }
        Subcommand::Serve => {
            anyhow::ensure!(
                c.backend == BackendKind::Cpu,
                "serving runs on the incremental decode engine, which only the CPU \
                 interpreter implements; rerun with --backend cpu"
            );
            let session = open()?;
            cmd_serve(&session, &c, args)?;
        }
    }
    Ok(())
}

/// `mase formats` — Table 1-style quick comparison on the LM, over
/// either execution backend.
fn cmd_formats<B: ExecBackend>(session: &Session, c: &CommonArgs, backend: B) -> Result<()> {
    let model = c.model_or("llama-sim");
    let meta = session.manifest.model(&model)?.clone();
    anyhow::ensure!(meta.kind == "lm", "formats comparison runs on the LM simulant");
    let w = pretrain::pretrain(session, &meta, None, &Default::default())?;
    let corpus = mase::data::MarkovCorpus::new(7);
    let n_batches = c.eval_batches.unwrap_or(4);
    let mut bs = Vec::new();
    for i in 0..n_batches {
        let toks = corpus.batch(1000 + i as u64, meta.batch, meta.seq_len);
        bs.push(mase::data::Batch {
            tokens: toks,
            labels: vec![0; meta.batch],
            batch: meta.batch,
            seq: meta.seq_len,
        });
    }
    let ev = mase::passes::Evaluator::new(backend, &meta, &w, &bs)?;
    let profile = mase::passes::profile_model(&ev.backend, &meta, &w, &bs[..1])?;
    let mut t =
        mase::util::Table::new(vec!["format", "config", "perplexity", "mem density", "arith density"]);
    for fmt in FormatKind::ALL {
        let bits = mase::formats::FormatSpec::default_bits(fmt);
        let sol = mase::passes::QuantSolution::uniform(fmt, bits, &meta, &profile);
        let acc = ev.accuracy(&sol)?;
        let p = mase::formats::Precision::new(bits, sol.fracs[0]);
        t.row(vec![
            fmt.name().to_string(),
            "W8A8".to_string(),
            format!("{:.2}", acc.perplexity()),
            format!("{:.2}x", mase::hw::memory_density(fmt, p)),
            format!("{:.1}x", mase::hw::arithmetic_density(fmt, p)),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

/// `mase generate` — KV-cached greedy autoregressive generation on the
/// incremental decode engine (PR 7), through the evaluator's `decode`
/// plumbing. Prompts come from the deterministic Markov corpus, so a
/// fixed seed yields bit-identical token streams at any `--threads`.
/// Only the CPU backend has the engine; PJRT bails with a pointer.
/// With `--weights model.mxa` the backend serves pre-packed weight
/// tensors — the printed "weight packs in-session" count drops to 0.
fn cmd_generate<B: ExecBackend>(
    session: &Session,
    c: &CommonArgs,
    args: &Args,
    backend: B,
) -> Result<()> {
    let model = c.model_or("toy-lm");
    let meta = session.manifest.model(&model)?.clone();
    anyhow::ensure!(
        meta.kind == "lm",
        "generation needs a causal LM; '{model}' is a {} (try --model toy-lm or llama-sim)",
        meta.kind
    );
    let spec = c.spec();
    let (fmt, bits) = (spec.kind, spec.bits);
    let n_seqs = flag_usize(args, "seqs", meta.batch)?;
    let prompt_len = flag_usize(args, "prompt-len", (meta.seq_len / 2).max(1))?;
    let n_tokens = flag_usize(args, "tokens", 8)?;
    anyhow::ensure!(
        prompt_len >= 1 && prompt_len + n_tokens <= meta.seq_len,
        "prompt {prompt_len} + {n_tokens} new tokens must fit model seq_len {}",
        meta.seq_len
    );
    let w = pretrain::pretrain(session, &meta, None, &Default::default())?;
    let prompts = mase::data::MarkovCorpus::new(7).batch(4242, n_seqs, prompt_len);
    let profile = mase::passes::ProfileData::uniform(&meta, 4.0);
    let sol = mase::passes::QuantSolution::uniform(fmt, bits, &meta, &profile);
    // Tally from before the evaluator exists, so artifact-backed runs can
    // prove zero pack calls across the WHOLE session, not just decode.
    let tally_before = mase::packed::kernel_tally();
    let ev = mase::passes::Evaluator::new(backend, &meta, &w, &[])?;
    let threads = c.threads;
    // PR 8 observability: with --trace, record the decode's counted work
    // and the packed-kernel dispatch delta at this single-threaded point.
    let reg = if c.trace_enabled() {
        mase::obs::Registry::new()
    } else {
        mase::obs::Registry::disabled()
    };
    let span = reg
        .span("decode/run")
        .tag("model", meta.name.as_str())
        .tag("fmt", fmt.name());
    let r = ev.decode(&sol, &prompts, n_seqs, prompt_len, n_tokens, threads)?;
    drop(span);
    r.stats.record_to(&reg, "decode/run");
    let kernels = mase::packed::kernel_tally().delta(&tally_before);
    kernels.record_to(&reg, "kernels");

    // The CI decode smoke greps the final line; keep these checks fatal.
    anyhow::ensure!(
        r.tokens.len() == n_seqs * n_tokens,
        "expected {} generated tokens, got {}",
        n_seqs * n_tokens,
        r.tokens.len()
    );
    anyhow::ensure!(r.loss.is_finite(), "non-finite loss: logits degenerated");

    println!(
        "model: {}  format: {} @ {} bits  backend: {}  threads: {threads}",
        meta.name,
        fmt.name(),
        bits,
        ev.backend.kind().name()
    );
    println!(
        "prefill {prompt_len} tokens x {n_seqs} seqs, then {n_tokens} greedy KV-cached steps/seq"
    );
    println!("seq0 tokens: {:?}", &r.tokens[..n_tokens.min(r.tokens.len())]);
    println!(
        "attention work: {} cached score dots over {} steps (prefill rows: {}, prefill dots: {})",
        r.stats.decode_score_dots, r.stats.steps, r.stats.full_attn_rows, r.stats.full_score_dots
    );
    println!(
        "weight packs in-session: {} (0 = every weight tensor served from a --weights artifact)",
        kernels.weight_packs
    );
    let per_tok_ms = r.decode_seconds * 1e3 / (n_seqs * n_tokens).max(1) as f64;
    let prefill_ms = r.prefill_seconds * 1e3 / (n_seqs * prompt_len).max(1) as f64;
    println!(
        "decode ok: {} tokens across {} seqs, loss {:.4}, {:.3} ms/token decode, {:.3} ms/token prefill",
        r.tokens.len(),
        n_seqs,
        r.loss,
        per_tok_ms,
        prefill_ms
    );
    finish_trace(c, &reg)?;
    Ok(())
}

/// `mase serve` — the PR 9 HTTP inference service: the decode engine
/// behind a continuous-batching scheduler on a plain `std::net`
/// listener. Blocks until the process is terminated (no signal handler
/// in the vendored set — SIGTERM's default disposition is the shutdown
/// path, fine for a `connection: close` service with no durable state).
/// `--weights model.mxa` warm-starts the engine from pre-packed tensors.
fn cmd_serve(session: &Session, c: &CommonArgs, args: &Args) -> Result<()> {
    use mase::serve::{BatchEngine, ServeConfig, ServeInfo, ServeOptions};
    let model = c.model_or("toy-lm");
    let meta = session.manifest.model(&model)?.clone();
    anyhow::ensure!(
        meta.kind == "lm",
        "serving needs a causal LM; '{model}' is a {} (try --model toy-lm or llama-sim)",
        meta.kind
    );
    let spec = c.spec();
    let (fmt, bits) = (spec.kind, spec.bits);
    let w = pretrain::pretrain(session, &meta, None, &Default::default())?;
    let profile = mase::passes::ProfileData::uniform(&meta, 4.0);
    let qcfg = mase::passes::QuantSolution::uniform(fmt, bits, &meta, &profile).to_qconfig();
    let be = cpu_backend_for(c.weights.as_deref())?;
    if let (Some(p), Some(h)) = (&c.weights, be.weights_hash()) {
        println!("packed weights: {} (content {})", p.display(), mase::util::hex16(h));
    }
    let graph = be.prepare(&meta, &w, &[])?;
    let lanes = flag_usize(args, "lanes", 4)?;
    let cfg = ServeConfig {
        lanes,
        queue_cap: flag_usize(args, "queue-cap", 32)?,
        queue_timeout_ms: flag_usize(args, "queue-timeout-ms", 2000)? as u64,
        default_max_tokens: flag_usize(args, "max-tokens", 8)?,
    };
    let mut engine = BatchEngine::new(&be, &graph, &meta, &w, fmt.name(), &qcfg, lanes)?;
    let info = ServeInfo {
        model: meta.name.clone(),
        fmt: fmt.name().to_string(),
        bits,
        vocab: meta.vocab,
        seq_len: meta.seq_len,
        lanes,
        width: engine.width(),
    };
    let opts = ServeOptions {
        port: flag_usize(args, "port", 0)? as u16,
        http_workers: flag_usize(args, "http-workers", 4)?,
        cfg,
    };
    // always record: /metrics is the service's observability surface
    let reg = mase::obs::Registry::new();
    mase::serve::serve(&mut engine, &info, &opts, &reg)
}

/// Print the PR 8 trace summary and export the registry. A bare
/// `--trace` prints the summary table only; `--trace FILE` additionally
/// writes the event stream: `--trace-format jsonl` (default, the
/// deterministic `mase-trace` stream) or `chrome` (wall-clock span
/// timelines for chrome://tracing / Perfetto).
fn finish_trace(c: &CommonArgs, reg: &mase::obs::Registry) -> Result<()> {
    if !reg.is_enabled() {
        return Ok(());
    }
    let summary = mase::obs::TraceSummary::from_registry(reg);
    if !summary.is_empty() {
        print!("\n{}", summary.render());
    }
    let Some(path) = c.trace_file() else {
        return Ok(());
    };
    let format = c.trace_format.as_deref().unwrap_or("jsonl");
    let body = match format {
        "jsonl" => mase::obs::jsonl::render(reg),
        "chrome" => format!("{}\n", mase::obs::chrome::registry_chrome_json(reg)),
        other => return Err(anyhow!("unknown --trace-format '{other}' (jsonl|chrome)")),
    };
    std::fs::write(path, body)?;
    println!("trace written to {path} ({format})");
    Ok(())
}

/// `mase pack` — dump the measured bit-packed layout and storage of every
/// quantization-searchable tensor of a model (the numbers `hw::memory`
/// budgets with), next to the analytic Eq. (1) bits. With `--out`:
///
///  * `FILE.mxa` — pack the model's REAL weights (cached pretrained
///    weights when present, else the deterministic init — exactly what a
///    CPU-backend session evaluates) into the content-addressed packed
///    artifact container. Load it back with `--weights FILE.mxa` for a
///    warm start with zero re-quantize and zero re-pack.
///  * anything else — the JSON layout manifest; its per-tensor weight
///    rows render through the same `TensorDesc` structs the `.mxa`
///    manifest serializes.
///
/// Uses `artifacts/manifest.json` when present, else a synthetic model
/// spec (`--layers/--d-model/--heads/--vocab/--seq` in table/JSON mode;
/// the synthetic zoo a CPU session would build in `.mxa` mode).
fn cmd_pack(c: &CommonArgs, args: &Args, dir: &Path) -> Result<()> {
    use mase::formats::Precision;
    use mase::packed::layout::{packed_bits_for, ElemLayout};
    use mase::packed::{source_hash, TensorDesc};
    use mase::util::json::Json;
    use std::collections::BTreeMap;

    let spec = c.spec();
    let (fmt, bits, frac) = (spec.kind, spec.bits, spec.frac);
    let model = c.model_or("opt-125m-sim");
    let to_mxa = c.out.as_deref().is_some_and(|o| o.ends_with(".mxa"));
    let meta = match mase::frontend::Manifest::load(dir) {
        Ok(man) => man.model(&model)?.clone(),
        // A `.mxa` must describe the graph a warm `--backend cpu` session
        // will build, and manifest-less CPU sessions fall back to the
        // synthetic zoo (`Session::open_for`) — so the artifact path
        // falls back the same way instead of to the hand-tuned spec.
        Err(_) if to_mxa => mase::frontend::Manifest::synthetic().model(&model)?.clone(),
        Err(_) => {
            println!(
                "(no manifest under {}; using a synthetic spec for '{model}' — \
                 tune with --layers/--d-model/--heads/--vocab/--seq)",
                dir.display()
            );
            mase::frontend::ModelMeta::synthetic(
                &model,
                flag_usize(args, "layers", 2)?,
                flag_usize(args, "d-model", 64)?,
                flag_usize(args, "heads", 2)?,
                flag_usize(args, "vocab", 512)?,
                flag_usize(args, "seq", 32)?,
                4,
                "classifier",
                8,
            )
        }
    };

    // The exact f32 bits a warm CPU-backend session will evaluate:
    // cached pretrained weights when present, else the deterministic
    // init — these are what the manifest's source hashes key on.
    let task = if meta.kind == "lm" { None } else { Some(c.task) };
    let weights = match Session::open_for(dir, BackendKind::Cpu) {
        Ok(session) => pretrain::pretrain(&session, &meta, task, &Default::default())?,
        Err(_) if !to_mxa => mase::frontend::init_params(&meta, 0xC0DE),
        Err(e) => return Err(e),
    };

    let mut g = mase::frontend::build_graph(&meta);
    let n = meta.num_qtensors();
    mase::frontend::apply_quant_to_graph(&mut g, fmt, &vec![bits; n], &vec![frac; n]);

    let lay = ElemLayout::new(fmt, Precision::new(bits, frac));
    println!(
        "model: {}  format: {}  knob: {}  elem: {} bits  shared exp: {} bits  pad/block: {} bits",
        meta.name,
        fmt.name(),
        lay.knob,
        lay.elem_bits,
        lay.shared_exp_bits,
        lay.padding_bits_per_group(),
    );

    let weight_ids: std::collections::BTreeSet<_> =
        g.ops.iter().flat_map(|o| o.params.iter().copied()).collect();
    let mut t = mase::util::Table::new(vec![
        "tensor", "kind", "shape", "analytic_B", "packed_B", "overhead",
    ]);
    let mut tensors = Vec::new();
    let (mut tot_analytic, mut tot_packed) = (0.0f64, 0u64);
    for &vid in &g.qtensor_values() {
        let v = g.value(vid);
        let analytic = v.ty.bits();
        let packed = packed_bits_for(v.ty.format, v.ty.precision, &v.ty.shape);
        let kind = if weight_ids.contains(&vid) { "weight" } else { "act" };
        t.row(vec![
            v.name.clone(),
            kind.to_string(),
            format!("{:?}", v.ty.shape),
            format!("{:.0}", analytic / 8.0),
            (packed / 8).to_string(),
            format!("{:+.1}%", (packed as f64 / analytic - 1.0) * 100.0),
        ]);
        tot_analytic += analytic;
        tot_packed += packed;
        // Weight rows render through the shared TensorDesc — the same
        // struct the .mxa manifest serializes; activations have no
        // packed-on-disk form and keep a plain record.
        let pspec = meta.param_spec.iter().find(|s| s.name == v.name);
        let mut o = match (kind, &v.ty.shape[..], pspec) {
            ("weight", [rows, cols], Some(ps)) => {
                let sz: usize = ps.shape.iter().product();
                TensorDesc {
                    name: v.name.clone(),
                    kind: kind.to_string(),
                    rows: *rows,
                    cols: *cols,
                    layout: ElemLayout::new(v.ty.format, v.ty.precision),
                    source_hash: source_hash(&weights[ps.offset..ps.offset + sz]),
                }
                .to_json()
            }
            _ => {
                let mut o = BTreeMap::new();
                o.insert("name".to_string(), Json::Str(v.name.clone()));
                o.insert("kind".to_string(), Json::Str(kind.to_string()));
                o
            }
        };
        o.insert(
            "shape".to_string(),
            Json::Arr(v.ty.shape.iter().map(|&d| Json::Num(d as f64)).collect()),
        );
        o.insert("analytic_bits".to_string(), Json::Num(analytic));
        o.insert("packed_bits".to_string(), Json::Num(packed as f64));
        tensors.push(Json::Obj(o));
    }
    println!("{}", t.render());
    println!(
        "totals: analytic {:.0} bytes, packed {} bytes ({:+.2}% measured overhead: shared \
         exponents + field guards + word alignment)",
        tot_analytic / 8.0,
        tot_packed / 8,
        (tot_packed as f64 / tot_analytic - 1.0) * 100.0,
    );

    let Some(out) = &c.out else { return Ok(()) };
    if to_mxa {
        // Pack through the interpreter's own path (same names, layouts
        // and qconfig as `generate`/`serve` uniform runs), then write the
        // content-addressed container atomically.
        let raw = mase::frontend::build_graph(&meta);
        let profile = mase::passes::ProfileData::uniform(&meta, 4.0);
        let qcfg = mase::passes::QuantSolution::uniform(fmt, bits, &meta, &profile).to_qconfig();
        let writer = mase::runtime::build_weights_artifact(&meta, &raw, &weights, spec, &qcfg)?;
        let n_tensors = writer.tensor_descs().count();
        let hash = writer.write_to(Path::new(out))?;
        println!(
            "packed artifact written to {out}: {n_tensors} tensors, content {}",
            mase::util::hex16(hash)
        );
        println!("(load it back with --weights {out} on cpu-backend commands for a zero-repack warm start)");
    } else {
        let mut root = BTreeMap::new();
        root.insert("schema".to_string(), Json::Str("mase-pack-manifest".to_string()));
        root.insert("version".to_string(), Json::Num(1.0));
        root.insert("model".to_string(), Json::Str(meta.name.clone()));
        root.insert("format".to_string(), Json::Str(fmt.name().to_string()));
        root.insert("knob".to_string(), Json::Num(lay.knob as f64));
        root.insert("elem_bits".to_string(), Json::Num(lay.elem_bits as f64));
        root.insert("shared_exp_bits".to_string(), Json::Num(lay.shared_exp_bits as f64));
        root.insert("pad_bits_per_block".to_string(), Json::Num(lay.padding_bits_per_group() as f64));
        root.insert("total_packed_bits".to_string(), Json::Num(tot_packed as f64));
        root.insert("tensors".to_string(), Json::Arr(tensors));
        // .tmp + rename: a re-pack over an existing manifest can never
        // leave a half-written file behind
        mase::util::write_atomic(Path::new(out), format!("{}\n", Json::Obj(root)).as_bytes())?;
        println!("layout manifest written to {out}");
    }
    Ok(())
}

/// `mase check` — run the PR 6 static analyzers and exit nonzero on any
/// error-level diagnostic. Two modes:
///
///  * `--sv PATH` — analyze SystemVerilog on disk (a file or every
///    `.sv` in a directory) with the real SV analyzer alone.
///  * default — quantize + parallelize a model (manifest model or a
///    synthetic spec, like `pack`) at `--fmt/--bits`, emit the design
///    in memory and run the full cross-layer check: SV analysis of
///    every file, the IR bitwidth contracts, and the emitted-parameter
///    agreement, at `--chan`-bit channels.
///
/// This drives the same `check::` entry points as the emit-pass gate
/// and the ci.sh `check` stage.
fn cmd_check(c: &CommonArgs, args: &Args, dir: &Path) -> Result<()> {
    use std::collections::BTreeMap;

    let report = if let Some(path) = args.get("sv") {
        let p = Path::new(path);
        let mut files = BTreeMap::new();
        if p.is_dir() {
            for entry in std::fs::read_dir(p)? {
                let fp = entry?.path();
                if fp.extension().is_some_and(|e| e == "sv") {
                    let name = fp
                        .file_name()
                        .map(|n| n.to_string_lossy().to_string())
                        .unwrap_or_default();
                    files.insert(name, std::fs::read_to_string(&fp)?);
                }
            }
        } else {
            let name = p
                .file_name()
                .map(|n| n.to_string_lossy().to_string())
                .unwrap_or_else(|| path.to_string());
            files.insert(name, std::fs::read_to_string(p)?);
        }
        anyhow::ensure!(!files.is_empty(), "no .sv files under {path}");
        println!("checking {} SV file(s) from {path}", files.len());
        mase::check::check_sv_files(&files)
    } else {
        let fmt = c.fmt;
        let bits = c.bits_or(5.0);
        let chan = flag_usize(args, "chan", mase::hw::DEFAULT_CHANNEL_BITS as usize)? as u64;
        let model = c.model_or("opt-125m-sim");
        let meta = match mase::frontend::Manifest::load(dir) {
            Ok(man) => man.model(&model)?.clone(),
            Err(_) => mase::frontend::ModelMeta::synthetic(
                &model,
                flag_usize(args, "layers", 2)?,
                flag_usize(args, "d-model", 32)?,
                flag_usize(args, "heads", 2)?,
                flag_usize(args, "vocab", 512)?,
                flag_usize(args, "seq", 32)?,
                4,
                "classifier",
                64,
            ),
        };
        let profile = mase::passes::ProfileData::uniform(&meta, 4.0);
        let mut g = mase::frontend::build_graph(&meta);
        mase::passes::QuantSolution::uniform(fmt, bits, &meta, &profile).apply(&mut g);
        mase::passes::parallelize(&mut g, &mase::hw::Device::u250(), 0.2);
        mase::passes::verify_boundary(&g, "parallelize")?;
        let design = mase::emit::emit_design(&g);
        println!(
            "checking {} emitted file(s) for '{}' ({} @ {} bits, {}-bit channels)",
            design.files.len(),
            meta.name,
            fmt.name(),
            bits,
            chan
        );
        mase::check::check_design(&design, &g, chan)
    };
    print!("{}", report.render());
    anyhow::ensure!(!report.has_errors(), "static checks failed");
    Ok(())
}

/// `mase trace` — the PR 8 observability driver. Default mode is
/// artifact-free (like `check`): quantize + parallelize a model
/// (manifest model or a synthetic spec) at `--fmt/--bits`, run the
/// cycle-approximate simulator with tracing over `--chan`-bit channels,
/// and export the event log:
///
///  * `--trace-format chrome` (default) — Chrome Trace Event JSON
///    loadable in chrome://tracing or Perfetto: one timeline track per
///    PE (node firings as slices) plus one per stalled channel. Slice
///    timestamps are simulated cycles, so the export is exactly as
///    deterministic as the simulator.
///  * `--trace-format jsonl` — the deterministic `mase-trace` JSONL
///    stream: per-node firing/busy/stall counters and per-edge transfer
///    counters (fixed-width hex, sorted by `(path, seq)`).
///
/// `--run e2e|sweep|generate` instead delegates to that subcommand with
/// tracing forced on (`mase trace --run sweep ...` == `mase sweep
/// --trace ...`).
fn cmd_trace(c: &CommonArgs, args: &Args, dir: &Path) -> Result<()> {
    let fmt = c.fmt;
    let bits = c.bits_or(5.0);
    let chan = flag_usize(args, "chan", mase::hw::DEFAULT_CHANNEL_BITS as usize)? as u64;
    let inferences = flag_usize(args, "inferences", 8)? as u64;
    let fifo_depth = flag_usize(args, "fifo", 4)? as u64;
    let model = c.model_or("opt-125m-sim");
    let meta = match mase::frontend::Manifest::load(dir) {
        Ok(man) => man.model(&model)?.clone(),
        Err(_) => mase::frontend::ModelMeta::synthetic(
            &model,
            flag_usize(args, "layers", 2)?,
            flag_usize(args, "d-model", 32)?,
            flag_usize(args, "heads", 2)?,
            flag_usize(args, "vocab", 512)?,
            flag_usize(args, "seq", 32)?,
            4,
            "classifier",
            64,
        ),
    };
    let profile = mase::passes::ProfileData::uniform(&meta, 4.0);
    let mut g = mase::frontend::build_graph(&meta);
    mase::passes::QuantSolution::uniform(fmt, bits, &meta, &profile).apply(&mut g);
    mase::passes::parallelize(&mut g, &mase::hw::Device::u250(), 0.2);
    mase::passes::verify_boundary(&g, "parallelize")?;
    let nodes = mase::sim::nodes_from_graph(&g);
    let cfg =
        mase::sim::SimConfig { inferences, fifo_depth, sequential: false, channel_bits: chan };
    let (report, trace) = mase::sim::simulate_traced(&nodes, &cfg);
    println!(
        "simulated '{}' ({} @ {} bits, {}-bit channels): {} nodes, {} inferences, {} cycles, \
         {} firings, {} channel-stall events",
        meta.name,
        fmt.name(),
        bits,
        chan,
        nodes.len(),
        inferences,
        report.cycles,
        trace.firings.len(),
        trace.stalls.len(),
    );

    let format = c.trace_format.as_deref().unwrap_or("chrome");
    let out = c.out.clone().unwrap_or_else(|| "trace.json".to_string());
    let body = match format {
        "chrome" => format!("{}\n", mase::obs::chrome::sim_chrome_json(&nodes, &report, &trace)),
        "jsonl" => {
            // Fold the sim accounting into a trace registry: counters
            // only (counted cycles, no wall-clock), so the stream is as
            // deterministic as the simulator.
            let reg = mase::obs::Registry::new();
            reg.counter("sim", "cycles", report.cycles);
            let mut firings = vec![0u64; nodes.len()];
            for f in &trace.firings {
                firings[f.node] += 1;
            }
            for (i, n) in nodes.iter().enumerate() {
                let path = format!("sim/node/{}", n.name);
                reg.counter(&path, "firings", firings[i]);
                reg.counter(&path, "busy_cycles", report.busy[i]);
                reg.counter(&path, "stalled_cycles", report.stalled[i]);
            }
            for e in &report.edges {
                let path = format!(
                    "sim/xfer/{}->{}#{}",
                    nodes[e.producer].name, nodes[e.consumer].name, e.slot
                );
                reg.counter(&path, "transfer_cycles", e.transfer_cycles);
                reg.counter(&path, "transfer_stalled", e.transfer_stalled);
            }
            mase::obs::jsonl::render(&reg)
        }
        other => return Err(anyhow!("unknown --trace-format '{other}' (chrome|jsonl)")),
    };
    std::fs::write(&out, body)?;
    println!("trace written to {out} ({format})");
    if format == "chrome" {
        println!("(load in chrome://tracing or https://ui.perfetto.dev — one track per PE)");
    }
    Ok(())
}

const HELP: &str = "mase — dataflow compiler for LLM inference with MX formats
usage: mase <subcommand> [flags]
  pretrain --all | --model M [--task T] [--steps N]
  profile  --model M [--task T]
  search   --model M [--task T] [--fmt mxint|int|bmf|bl] [--algorithm tpe|random|qmc|nsga2] [--trials N] [--sw-only]
  sweep    [--models M,..] [--tasks T,..|all] [--fmts F,..] [--trials N] [--qat-steps N] [--sw-only]
           (the Fig. 6 grid through one shared eval cache; with --cache a
            re-run of the same sweep performs zero re-simulations)
  emit     --model M [--task T] [--out DIR]
  e2e      --model M [--task T] [--trials N]
  ir       --model M
  check    [--sv PATH] [--model M] [--fmt F] [--bits N] [--chan W]
           (static analysis: real SV analyzer + cross-layer bitwidth
            contracts, exits nonzero on error diagnostics; default mode
            emits a design in memory and checks it end to end, --sv
            analyzes .sv files on disk; artifact-free)
  pack     --model M [--task T] [--fmt F] [--bits N] [--frac N] [--out FILE.json|FILE.mxa]
           (measured bit-packed layout + bytes per tensor vs analytic
            Eq. 1; artifact-free — synthesizes a model spec if needed.
            --out FILE.mxa packs the model's real weights into the
            content-addressed .mxa container instead: chunked, FNV-1a/64
            hashed, streamed back by --weights with zero re-pack)
  formats  [--model llama-sim]
  generate [--model toy-lm] [--tokens N] [--prompt-len N] [--seqs N] [--fmt F] [--bits N]
           (KV-cached greedy decode through the incremental engine;
            needs --backend cpu — prints ms/token, the counted attention
            work and the in-session weight-pack count; bit-identical
            output at any --threads)
  serve    [--model toy-lm] [--fmt F] [--bits N] [--port N] [--lanes N]
           [--queue-cap N] [--queue-timeout-ms N] [--max-tokens N]
           [--http-workers N]
           (HTTP inference service over the decode engine with a
            continuous-batching scheduler; needs --backend cpu;
            POST /v1/generate, GET /healthz, GET /metrics; --port 0
            binds an ephemeral port, printed on stdout; batched tokens
            are bit-identical to per-request sequential decodes)
  trace    [--model M] [--fmt F] [--bits N] [--chan W] [--inferences N]
           [--out FILE] [--trace-format chrome|jsonl]
           (artifact-free simulator tracing: per-PE firing/stall
            timelines as Chrome Trace JSON for chrome://tracing /
            Perfetto, or the deterministic mase-trace JSONL stream;
            --run e2e|sweep|generate delegates with tracing forced on)
common: --artifacts DIR (default ./artifacts)
        --backend pjrt|cpu (execution backend for evaluate/profile;
            cpu = the artifact-free packed-arithmetic interpreter —
            search/e2e/sweep/profile/formats run on a bare host, scored
            under disjoint eval-cache scopes; no QAT, untrained weights
            unless artifacts/weights/ has cached ones)
        --weights FILE.mxa (cpu backend, search/e2e/emit/sweep/generate/
            serve: stream pre-packed weight tensors from a `mase pack
            --out FILE.mxa` artifact — zero re-quantize/re-pack on
            matching tensors, loader fails closed on corruption, and the
            artifact's content hash joins the eval-cache scope)
        --threads N (search eval workers; 0 = auto, also MASE_THREADS)
        --batch N   (search proposals per ask/tell round, default 8)
        --cache FILE (persistent eval cache for search/sweep/e2e/emit)
        --tpe-mean-lie (TPE batches lie at the observed mean, not the min)
        --trace [FILE] (search/e2e/emit/sweep/generate: record the
            deterministic trace/metrics stream, print a summary table;
            with FILE, export it — --trace-format jsonl|chrome)";
