//! `quantize` pass (Table 2): turn a precision assignment into (a) IR
//! value types and (b) the f32[V, 2] quant-config tensor the HLO eval
//! artifacts consume. Supports uniform baselines (int8, MXInt8, MXInt4/6)
//! and per-tensor mixed-precision vectors from the search pass; for fixed
//! point, fraction widths are calibrated from profile absmax (§5.1's
//! "int8" baseline) unless searched explicitly (MP int).

use super::profile::ProfileData;
use crate::formats::{fixed::calibrate_frac, FormatKind, Precision};
use crate::frontend::ModelMeta;
use crate::ir::Graph;

/// A complete quantization assignment for one model.
#[derive(Debug, Clone)]
pub struct QuantSolution {
    pub fmt: FormatKind,
    /// Per-qtensor "bits" knob (mantissa / width / exponent bits).
    pub bits: Vec<f32>,
    /// Per-qtensor fraction widths (fixed point only).
    pub fracs: Vec<f32>,
}

impl QuantSolution {
    /// Uniform solution (e.g. int8, MXInt8, MXInt6, MXInt4 baselines).
    /// Fixed point calibrates per-tensor fractions from the profile.
    pub fn uniform(fmt: FormatKind, bits: f32, meta: &ModelMeta, profile: &ProfileData) -> Self {
        let v = meta.num_qtensors();
        let fracs = match fmt {
            FormatKind::Int => {
                (0..v).map(|i| calibrate_frac(bits, profile.absmax[i] as f32)).collect()
            }
            _ => vec![0.0; v],
        };
        Self { fmt, bits: vec![bits; v], fracs }
    }

    /// Decode a search vector. MXInt/BMF/BL: x = per-tensor bits (len V).
    /// Int: x = per-tensor widths ++ per-tensor fraction *offsets* from
    /// the calibrated value (len 2V) — the paper's N^2v fixed-point space.
    pub fn from_search_vector(
        fmt: FormatKind,
        x: &[f64],
        meta: &ModelMeta,
        profile: &ProfileData,
    ) -> Self {
        let v = meta.num_qtensors();
        match fmt {
            FormatKind::Int => {
                assert_eq!(x.len(), 2 * v, "int search space is 2V");
                let bits: Vec<f32> = x[..v].iter().map(|b| b.round() as f32).collect();
                let fracs: Vec<f32> = (0..v)
                    .map(|i| {
                        calibrate_frac(bits[i], profile.absmax[i] as f32) + x[v + i].round() as f32
                    })
                    .collect();
                Self { fmt, bits, fracs }
            }
            _ => {
                assert_eq!(x.len(), v, "block-format search space is V");
                Self { fmt, bits: x.iter().map(|b| b.round() as f32).collect(), fracs: vec![0.0; v] }
            }
        }
    }

    /// Flatten into the f32[V, 2] row-major quant-config tensor.
    pub fn to_qconfig(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.bits.len() * 2);
        for i in 0..self.bits.len() {
            out.push(self.bits[i]);
            out.push(self.fracs.get(i).copied().unwrap_or(0.0));
        }
        out
    }

    /// Element-weighted average bitwidth of the model (the `b` in Eq. 4),
    /// computed over the IR's searchable values.
    pub fn average_bitwidth(&self, g: &Graph) -> f64 {
        let mut bits = 0.0f64;
        let mut elems = 0.0f64;
        for &vid in &g.qtensor_values() {
            let v = g.value(vid);
            let qi = v.qtensor.unwrap();
            let p = Precision::new(self.bits[qi], self.fracs.get(qi).copied().unwrap_or(0.0));
            let e = v.ty.elements() as f64;
            bits += e * p.average_bitwidth(self.fmt);
            elems += e;
        }
        if elems == 0.0 {
            0.0
        } else {
            bits / elems
        }
    }

    /// Apply to the IR (types on searchable values).
    pub fn apply(&self, g: &mut Graph) {
        crate::frontend::apply_quant_to_graph(g, self.fmt, &self.bits, &self.fracs);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::manifest::ModelMeta;

    fn setup() -> (ModelMeta, ProfileData) {
        let m = ModelMeta::synthetic("t", 2, 32, 2, 512, 32, 4, "classifier", 64);
        let p = ProfileData::uniform(&m, 4.0);
        (m, p)
    }

    #[test]
    fn uniform_mxint8() {
        let (m, p) = setup();
        let s = QuantSolution::uniform(FormatKind::MxInt, 7.0, &m, &p);
        assert!(s.bits.iter().all(|&b| b == 7.0));
        let mut g = crate::frontend::build_graph(&m);
        s.apply(&mut g);
        assert!((s.average_bitwidth(&g) - 8.25).abs() < 1e-6);
    }

    #[test]
    fn int_calibration_from_profile() {
        let (m, p) = setup();
        let s = QuantSolution::uniform(FormatKind::Int, 8.0, &m, &p);
        // absmax 4.0 -> int bits 2 -> frac = 8-1-2 = 5
        assert!(s.fracs.iter().all(|&f| f == 5.0));
    }

    #[test]
    fn search_vector_rounding() {
        let (m, p) = setup();
        let v = m.num_qtensors();
        let x = vec![4.4f64; v];
        let s = QuantSolution::from_search_vector(FormatKind::MxInt, &x, &m, &p);
        assert!(s.bits.iter().all(|&b| b == 4.0));
    }

    #[test]
    fn int_search_vector_has_2v_dims() {
        let (m, p) = setup();
        let v = m.num_qtensors();
        let mut x = vec![6.0f64; v];
        x.extend(vec![1.0f64; v]); // frac offset +1
        let s = QuantSolution::from_search_vector(FormatKind::Int, &x, &m, &p);
        assert!(s.fracs.iter().all(|&f| f == calibrate_frac(6.0, 4.0) + 1.0));
    }

    #[test]
    fn qconfig_layout_interleaved() {
        let (m, p) = setup();
        let s = QuantSolution::uniform(FormatKind::Int, 8.0, &m, &p);
        let q = s.to_qconfig();
        assert_eq!(q.len(), 2 * m.num_qtensors());
        assert_eq!(q[0], 8.0);
        assert_eq!(q[1], 5.0);
    }

    #[test]
    fn mixed_precision_lowers_average_bits() {
        let (m, p) = setup();
        let mut g = crate::frontend::build_graph(&m);
        let hi = QuantSolution::uniform(FormatKind::MxInt, 7.0, &m, &p);
        let mut bits = vec![7.0f32; m.num_qtensors()];
        for b in bits.iter_mut().step_by(2) {
            *b = 3.0;
        }
        let lo = QuantSolution { fmt: FormatKind::MxInt, bits, fracs: vec![0.0; m.num_qtensors()] };
        hi.apply(&mut g);
        let b_hi = hi.average_bitwidth(&g);
        lo.apply(&mut g);
        let b_lo = lo.average_bitwidth(&g);
        assert!(b_lo < b_hi);
    }
}
