//! `evaluate` pass (Table 2): source-level estimation of both halves of
//! the co-design — model accuracy via an execution backend
//! ([`crate::runtime::ExecBackend`]: PJRT eval artifacts or the packed
//! CPU interpreter), hardware area/throughput/energy via the regression
//! models — combined by the search objective of Eq. (4):
//!
//! `objective = acc + k/b + k'*theta + k''/A`

use super::parallelize::{parallelize, DesignPoint};
use super::quantize::QuantSolution;
use crate::data::Batch;
use crate::eval::EvalAccumulator;
use crate::frontend::ModelMeta;
use crate::hw::Device;
use crate::ir::Graph;
use crate::runtime::ExecBackend;
use anyhow::Result;

/// Hyperparameters of Eq. (4). `k` trades accuracy against bits; `k'`
/// and `k''` normalize throughput and area into the accuracy scale (the
/// paper: "k, k', k'' are hyperparameters that normalize these design
/// constraints"). `hw_aware = false` reproduces the SW-only objective of
/// Fig. 4 (`acc + k/b`).
#[derive(Debug, Clone, Copy)]
pub struct Objective {
    pub k: f64,
    pub k_theta: f64,
    pub k_area: f64,
    pub hw_aware: bool,
}

impl Default for Objective {
    fn default() -> Self {
        // theta ~ 1e4..1e6 inf/s, A ~ 1e4..1e6 LUTs on this testbed.
        Self { k: 0.6, k_theta: 2e-8, k_area: 3e3, hw_aware: true }
    }
}

impl Objective {
    pub fn sw_only() -> Self {
        Self { hw_aware: false, ..Self::default() }
    }

    /// Scalar value (maximized) + component vector for NSGA-II.
    pub fn score(&self, acc: f64, avg_bits: f64, dp: &DesignPoint) -> (f64, Vec<f64>) {
        let mut comps = vec![acc, self.k / avg_bits.max(1e-9)];
        if self.hw_aware {
            comps.push(self.k_theta * dp.throughput);
            comps.push(self.k_area / dp.area_luts.max(1.0));
        }
        (comps.iter().sum(), comps)
    }
}

/// Full result of evaluating one quantization solution.
#[derive(Debug, Clone)]
pub struct EvalResult {
    pub accuracy: f64,
    pub mean_loss: f64,
    pub perplexity: f64,
    pub avg_bits: f64,
    pub design: DesignPoint,
    pub value: f64,
    pub objectives: Vec<f64>,
}

/// Bundles everything needed to score a solution for one (model, task),
/// generic over the execution backend `B` (the PJRT adapter or the
/// packed-arithmetic CPU interpreter — see [`crate::runtime::backend`]).
///
/// The evaluator is immutable after construction and `Sync`: the
/// parallel search pass shares one `&Evaluator` across its worker
/// threads (`run_batched` -> `par_map`), so every method takes `&self`
/// and all interior mutability (the PJRT runtime's executable cache) is
/// behind locks. The assertion below turns any future regression — in
/// either backend — into a compile error.
pub struct Evaluator<'a, B: ExecBackend> {
    pub backend: B,
    pub meta: &'a ModelMeta,
    pub weights: &'a [f32],
    pub batches: &'a [Batch],
    pub device: Device,
    pub budget_frac: f64,
    pub objective: Objective,
    /// IR template (unquantized); cloned per evaluation.
    pub graph: Graph,
    /// Backend-owned per-(weights, batches) state, built once and reused
    /// across every trial (§Perf/L3: for PJRT these are the weight/batch
    /// literals — the weights vector alone is 0.1-3 MB copied per batch
    /// per trial otherwise).
    prep: B::Prepared,
}

impl<'a, B: ExecBackend> Evaluator<'a, B> {
    /// Build the evaluator, preparing backend state. Fails cleanly (no
    /// panics) when the backend cannot prepare the tensors.
    pub fn new(
        backend: B,
        meta: &'a ModelMeta,
        weights: &'a [f32],
        batches: &'a [Batch],
    ) -> Result<Self> {
        let prep = backend.prepare(meta, weights, batches)?;
        Ok(Self {
            backend,
            meta,
            weights,
            batches,
            device: Device::u250(),
            budget_frac: 0.4,
            objective: Objective::default(),
            graph: crate::frontend::build_graph(meta),
            prep,
        })
    }

    /// Accuracy/loss of a solution via the execution backend.
    pub fn accuracy(&self, sol: &QuantSolution) -> Result<EvalAccumulator> {
        self.accuracy_with(sol, sol.fmt.name(), self.weights)
    }

    /// Same but with an explicit format/emulation tag (e.g.
    /// "mxint_pallas", which PJRT maps to the `eval_mxint_pallas`
    /// artifact) and/or alternative weights (QAT-tuned copies).
    pub fn accuracy_with(
        &self,
        sol: &QuantSolution,
        fmt_tag: &str,
        weights: &[f32],
    ) -> Result<EvalAccumulator> {
        let qcfg = sol.to_qconfig();
        let scores =
            self.backend.eval(&self.prep, self.meta, self.batches, fmt_tag, &qcfg, weights)?;
        let mut acc = EvalAccumulator::default();
        for (b, score) in self.batches.iter().zip(scores) {
            let examples = if self.meta.kind == "lm" {
                b.batch * (b.seq - 1) // next-token positions
            } else {
                b.batch
            };
            acc.add_batch(score.loss, score.correct, examples);
        }
        Ok(acc)
    }

    /// Hardware half: quantize + parallelize the IR clone, with the IR
    /// verifier run at each pass boundary (PR 6). A graph the verifier
    /// rejects fails the flow here, with every finding listed, instead
    /// of feeding garbage into the cost models and the emitter.
    pub fn hardware(&self, sol: &QuantSolution) -> Result<(DesignPoint, f64, Graph)> {
        let mut g = self.graph.clone();
        sol.apply(&mut g);
        super::verify_boundary(&g, "quantize")?;
        let dp = parallelize(&mut g, &self.device, self.budget_frac);
        super::verify_boundary(&g, "parallelize")?;
        let bits = sol.average_bitwidth(&g);
        Ok((dp, bits, g))
    }

    /// Autoregressive decode profile through the backend's incremental
    /// engine (the `mase generate` entry point): greedily generate
    /// `n_tokens` per sequence from `prompts` (`[n_seqs, prompt_len]`,
    /// sequence-major) under the solution's format/precision config,
    /// fanning sequence groups over `threads` workers. Only backends
    /// with a KV-cached engine support this (the CPU interpreter);
    /// others bail with a pointer to `--backend cpu`.
    pub fn decode(
        &self,
        sol: &QuantSolution,
        prompts: &[i32],
        n_seqs: usize,
        prompt_len: usize,
        n_tokens: usize,
        threads: usize,
    ) -> Result<crate::runtime::DecodeReport> {
        let qcfg = sol.to_qconfig();
        self.backend.profile_decode(
            self.meta,
            self.weights,
            sol.fmt.name(),
            &qcfg,
            prompts,
            n_seqs,
            prompt_len,
            n_tokens,
            threads,
        )
    }

    /// Full co-design evaluation (the `evaluate` pass proper).
    pub fn evaluate(&self, sol: &QuantSolution) -> Result<EvalResult> {
        self.evaluate_with_weights(sol, self.weights)
    }

    /// Co-design evaluation with alternative weights (QAT-tuned copies).
    pub fn evaluate_with_weights(&self, sol: &QuantSolution, weights: &[f32]) -> Result<EvalResult> {
        let acc = self.accuracy_with(sol, sol.fmt.name(), weights)?;
        let (dp, avg_bits, _g) = self.hardware(sol)?;
        let (value, objectives) = self.objective.score(acc.accuracy(), avg_bits, &dp);
        Ok(EvalResult {
            accuracy: acc.accuracy(),
            mean_loss: acc.mean_loss(),
            perplexity: acc.perplexity(),
            avg_bits,
            design: dp,
            value,
            objectives,
        })
    }
}

// Compile-time guarantee that the search pass may share the evaluator
// across threads — asserted for BOTH backends. CAVEAT for whoever swaps
// rust/vendor/xla for the real xla-rs bindings: FFI crates often carry
// `unsafe impl Send/Sync` over raw pointers, so this assertion may still
// pass while the underlying PJRT client races. The real client is NOT
// thread-safe (see coordinator::pretrain::pretrain_all) — give each
// worker its own client, or serialize `Runtime::execute*` behind a lock,
// before enabling `threads > 1` against real PJRT. The CPU interpreter
// has no such caveat: it is a pure function of its inputs.
#[allow(dead_code)]
fn _assert_evaluator_is_sync() {
    fn is_sync<T: Sync>() {}
    is_sync::<Evaluator<'static, crate::runtime::PjrtBackend<'static>>>();
    is_sync::<Evaluator<'static, crate::runtime::CpuBackend>>();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn objective_components() {
        let o = Objective::default();
        let dp = DesignPoint {
            area_luts: 1e5,
            throughput: 1e5,
            latency_cycles: 1e6,
            offchip_bits: 0.0,
            utilization: 0.1,
        };
        let (v, comps) = o.score(0.9, 4.25, &dp);
        assert_eq!(comps.len(), 4);
        assert!((v - comps.iter().sum::<f64>()).abs() < 1e-12);
        // higher accuracy -> higher objective
        let (v2, _) = o.score(0.95, 4.25, &dp);
        assert!(v2 > v);
        // fewer bits -> higher objective
        let (v3, _) = o.score(0.9, 3.0, &dp);
        assert!(v3 > v);
    }

    #[test]
    fn sw_only_ignores_hardware() {
        let o = Objective::sw_only();
        let dp_a = DesignPoint { area_luts: 1.0, throughput: 1e9, latency_cycles: 0.0, offchip_bits: 0.0, utilization: 0.0 };
        let dp_b = DesignPoint { area_luts: 1e9, throughput: 1.0, latency_cycles: 0.0, offchip_bits: 0.0, utilization: 0.9 };
        let (va, _) = o.score(0.9, 4.0, &dp_a);
        let (vb, _) = o.score(0.9, 4.0, &dp_b);
        assert_eq!(va, vb);
    }
}
