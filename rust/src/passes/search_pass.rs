//! `search` pass (Table 2, §4.3): resource-constrained mixed-precision
//! search. Orchestrates one of the [`crate::search`] algorithms over the
//! per-tensor precision space S' (= N^V for MXInt, N^2V for fixed point),
//! scoring each trial with the `evaluate` pass. Optionally interleaves
//! QAT fine-tune steps (small models, Fig. 6) — the "trainable IR" in
//! action.

use super::evaluate::{EvalResult, Evaluator};
use super::profile::ProfileData;
use super::quantize::QuantSolution;
use crate::data::Task;
use crate::formats::FormatKind;
use crate::runtime::TensorData;
use crate::search::{best_curve, run, Algorithm, Space, Trial};
use anyhow::Result;

#[derive(Debug, Clone)]
pub struct SearchConfig {
    pub algorithm: Algorithm,
    pub trials: usize,
    pub fmt: FormatKind,
    pub seed: u64,
    /// QAT fine-tune steps per trial (0 = PTQ).
    pub qat_steps: usize,
    pub qat_lr: f32,
    /// Bits range searched per tensor.
    pub bits_lo: f64,
    pub bits_hi: f64,
}

impl Default for SearchConfig {
    fn default() -> Self {
        Self {
            algorithm: Algorithm::Tpe,
            trials: 64,
            fmt: FormatKind::MxInt,
            seed: 0,
            qat_steps: 0,
            qat_lr: 0.002,
            bits_lo: 2.0,
            bits_hi: 8.0,
        }
    }
}

#[derive(Debug, Clone)]
pub struct SearchOutcome {
    pub history: Vec<Trial>,
    pub best: QuantSolution,
    pub best_eval: EvalResult,
    /// Fine-tuned weights if QAT ran (else None).
    pub tuned_weights: Option<Vec<f32>>,
}

/// The search space for a format family (paper §4.1's reduction: MXInt
/// searches V mantissa widths; fixed point searches 2V width+frac knobs).
pub fn space_for(fmt: FormatKind, num_qtensors: usize, lo: f64, hi: f64) -> Space {
    match fmt {
        FormatKind::Int => {
            let mut l = vec![lo.max(3.0); num_qtensors];
            let mut h = vec![hi; num_qtensors];
            l.extend(vec![-2.0; num_qtensors]); // frac offset from calibration
            h.extend(vec![2.0; num_qtensors]);
            Space::new(l, h)
        }
        _ => Space::uniform(num_qtensors, lo, hi),
    }
}

/// Run the full search for one (model, task, format).
pub fn run_search(
    ev: &Evaluator,
    profile: &ProfileData,
    task: Task,
    cfg: &SearchConfig,
) -> Result<SearchOutcome> {
    let v = ev.meta.num_qtensors();
    let space = space_for(cfg.fmt, v, cfg.bits_lo, cfg.bits_hi);

    // Optional per-trial QAT: fine-tune a scratch copy of the weights on
    // the train split under the trial's quantization, then evaluate.
    let qat_artifact = if cfg.qat_steps > 0 {
        Some(ev.meta.artifact(&format!("qat_{}", cfg.fmt.name()))?.to_string())
    } else {
        None
    };
    let train_batches = if cfg.qat_steps > 0 {
        crate::data::batches(task, 0, cfg.qat_steps, ev.meta.batch, ev.meta.seq_len)
    } else {
        Vec::new()
    };

    let mut best_value = f64::NEG_INFINITY;
    let mut best: Option<(QuantSolution, EvalResult, Option<Vec<f32>>)> = None;

    let history = run(cfg.algorithm, space, cfg.seed, cfg.trials, |x| {
        let sol = QuantSolution::from_search_vector(cfg.fmt, x, ev.meta, profile);
        // QAT fine-tune on a scratch copy
        let tuned: Option<Vec<f32>> = qat_artifact.as_ref().map(|art| {
            let mut w = ev.weights.to_vec();
            let qcfg = sol.to_qconfig();
            for b in &train_batches {
                if let Ok(out) = ev.rt.execute(
                    art,
                    &[
                        TensorData::f32(&w, &[ev.meta.param_size as i64]),
                        TensorData::i32(&b.tokens, &[b.batch as i64, b.seq as i64]),
                        TensorData::i32(&b.labels, &[b.batch as i64]),
                        TensorData::f32(&qcfg, &[v as i64, 2]),
                        TensorData::scalar_f32(cfg.qat_lr),
                    ],
                ) {
                    if let Ok(new_w) = out[0].to_vec_f32() {
                        w = new_w;
                    }
                }
            }
            w
        });

        let result = match &tuned {
            Some(w) => ev.evaluate_with_weights(&sol, w),
            None => ev.evaluate(&sol),
        };
        match result {
            Ok(r) => {
                if r.value > best_value {
                    best_value = r.value;
                    best = Some((sol, r.clone(), tuned));
                }
                (r.value, r.objectives)
            }
            Err(e) => {
                eprintln!("trial failed: {e:#}");
                (f64::NEG_INFINITY, vec![])
            }
        }
    });

    let (best_sol, best_eval, tuned_weights) =
        best.ok_or_else(|| anyhow::anyhow!("no successful trials"))?;
    Ok(SearchOutcome { history, best: best_sol, best_eval, tuned_weights })
}

/// Convenience: the incumbent-value curve for Fig. 4.
pub fn outcome_curve(outcome: &SearchOutcome) -> Vec<f64> {
    best_curve(&outcome.history)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn space_dims_per_format() {
        assert_eq!(space_for(FormatKind::MxInt, 18, 2.0, 8.0).dims(), 18);
        assert_eq!(space_for(FormatKind::Int, 18, 2.0, 8.0).dims(), 36);
        assert_eq!(space_for(FormatKind::Bl, 18, 2.0, 8.0).dims(), 18);
    }

    #[test]
    fn int_space_widths_at_least_3_bits() {
        let s = space_for(FormatKind::Int, 4, 2.0, 8.0);
        assert!(s.lo[..4].iter().all(|&l| l >= 3.0));
        assert!(s.lo[4..].iter().all(|&l| l == -2.0));
    }
}
