//! `search` pass (Table 2, §4.3): resource-constrained mixed-precision
//! search. Orchestrates one of the [`crate::search`] algorithms over the
//! per-tensor precision space S' (= N^V for MXInt, N^2V for fixed point),
//! scoring each trial with the `evaluate` pass. Optionally interleaves
//! QAT fine-tune steps (small models, Fig. 6) — the "trainable IR" in
//! action.
//!
//! Trials are evaluated through the batched parallel driver
//! [`crate::search::run_batched`]: `cfg.batch` proposals per ask/tell
//! round fan out over `cfg.threads` workers, with a memo cache keyed on
//! the *rounded* search vector (the exact quantization
//! [`QuantSolution::from_search_vector`] applies), so duplicate
//! proposals are never re-simulated. With a fixed seed the trial history
//! is identical for every thread count — see the batch-order convention
//! in the `search` module docs.

use super::evaluate::{EvalResult, Evaluator};
use super::profile::ProfileData;
use super::quantize::QuantSolution;
use crate::data::Task;
use crate::formats::FormatKind;
use crate::runtime::TensorData;
use crate::search::{best_curve, run_batched, Algorithm, BatchOptions, MemoKey, Space, Trial};
use crate::util::pool::threads_from_env;
use anyhow::Result;

#[derive(Debug, Clone)]
pub struct SearchConfig {
    pub algorithm: Algorithm,
    pub trials: usize,
    pub fmt: FormatKind,
    pub seed: u64,
    /// QAT fine-tune steps per trial (0 = PTQ).
    pub qat_steps: usize,
    pub qat_lr: f32,
    /// Bits range searched per tensor.
    pub bits_lo: f64,
    pub bits_hi: f64,
    /// Proposals evaluated concurrently per ask/tell round (1 = the
    /// serial cadence).
    pub batch: usize,
    /// Worker threads for trial evaluation; 0 = the `MASE_THREADS` env
    /// var, falling back to all cores minus one (see
    /// [`crate::util::pool::threads_from_env`]).
    pub threads: usize,
}

impl Default for SearchConfig {
    fn default() -> Self {
        Self {
            algorithm: Algorithm::Tpe,
            trials: 64,
            fmt: FormatKind::MxInt,
            seed: 0,
            qat_steps: 0,
            qat_lr: 0.002,
            bits_lo: 2.0,
            bits_hi: 8.0,
            batch: 8,
            threads: 0,
        }
    }
}

#[derive(Debug, Clone)]
pub struct SearchOutcome {
    pub history: Vec<Trial>,
    pub best: QuantSolution,
    pub best_eval: EvalResult,
    /// Fine-tuned weights if QAT ran (else None).
    pub tuned_weights: Option<Vec<f32>>,
}

/// The search space for a format family (paper §4.1's reduction: MXInt
/// searches V mantissa widths; fixed point searches 2V width+frac knobs).
pub fn space_for(fmt: FormatKind, num_qtensors: usize, lo: f64, hi: f64) -> Space {
    match fmt {
        FormatKind::Int => {
            let mut l = vec![lo.max(3.0); num_qtensors];
            let mut h = vec![hi; num_qtensors];
            l.extend(vec![-2.0; num_qtensors]); // frac offset from calibration
            h.extend(vec![2.0; num_qtensors]);
            Space::new(l, h)
        }
        _ => Space::uniform(num_qtensors, lo, hi),
    }
}

/// Run the full search for one (model, task, format).
pub fn run_search(
    ev: &Evaluator,
    profile: &ProfileData,
    task: Task,
    cfg: &SearchConfig,
) -> Result<SearchOutcome> {
    let v = ev.meta.num_qtensors();
    let space = space_for(cfg.fmt, v, cfg.bits_lo, cfg.bits_hi);

    // Optional per-trial QAT: fine-tune a scratch copy of the weights on
    // the train split under the trial's quantization, then evaluate.
    let qat_artifact = if cfg.qat_steps > 0 {
        Some(ev.meta.artifact(&format!("qat_{}", cfg.fmt.name()))?.to_string())
    } else {
        None
    };
    let train_batches = if cfg.qat_steps > 0 {
        crate::data::batches(task, 0, cfg.qat_steps, ev.meta.batch, ev.meta.seq_len)
    } else {
        Vec::new()
    };

    // QAT fine-tune on a scratch copy — a pure function of the solution
    // (fixed train stream, no shared mutable state), so workers can call
    // it concurrently.
    let qat_tune = |sol: &QuantSolution| -> Option<Vec<f32>> {
        qat_artifact.as_ref().map(|art| {
            let mut w = ev.weights.to_vec();
            let qcfg = sol.to_qconfig();
            for b in &train_batches {
                if let Ok(out) = ev.rt.execute(
                    art,
                    &[
                        TensorData::f32(&w, &[ev.meta.param_size as i64]),
                        TensorData::i32(&b.tokens, &[b.batch as i64, b.seq as i64]),
                        TensorData::i32(&b.labels, &[b.batch as i64]),
                        TensorData::f32(&qcfg, &[v as i64, 2]),
                        TensorData::scalar_f32(cfg.qat_lr),
                    ],
                ) {
                    if let Ok(new_w) = out[0].to_vec_f32() {
                        w = new_w;
                    }
                }
            }
            w
        })
    };

    // Running winner, tracked across workers. The tie-break on the
    // rounded key makes the final content a pure max over the set of
    // evaluated configurations — independent of worker arrival order,
    // preserving the determinism guarantee. Every distinct config passes
    // through the objective exactly once (run_batched memoizes
    // duplicates), so the winner's full EvalResult and QAT weights are
    // captured here without a second evaluation.
    struct BestTrial {
        value: f64,
        key: Vec<u64>,
        sol: QuantSolution,
        eval: EvalResult,
        tuned: Option<Vec<f32>>,
    }
    let best: std::sync::Mutex<Option<BestTrial>> = std::sync::Mutex::new(None);

    let opts = BatchOptions {
        batch: cfg.batch.max(1),
        threads: threads_from_env(cfg.threads),
        memo: MemoKey::Rounded,
    };
    let history = run_batched(cfg.algorithm, space, cfg.seed, cfg.trials, &opts, |x| {
        let sol = QuantSolution::from_search_vector(cfg.fmt, x, ev.meta, profile);
        let tuned = qat_tune(&sol);
        let result = match &tuned {
            Some(w) => ev.evaluate_with_weights(&sol, w),
            None => ev.evaluate(&sol),
        };
        match result {
            Ok(r) => {
                if r.value.is_finite() {
                    let key = MemoKey::Rounded.key(x);
                    let mut b = best.lock().unwrap();
                    let better = match &*b {
                        None => true,
                        Some(cur) => {
                            r.value > cur.value || (r.value == cur.value && key < cur.key)
                        }
                    };
                    if better {
                        *b = Some(BestTrial {
                            value: r.value,
                            key,
                            sol,
                            eval: r.clone(),
                            tuned,
                        });
                    }
                }
                (r.value, r.objectives)
            }
            Err(e) => {
                eprintln!("trial failed: {e:#}");
                (f64::NEG_INFINITY, vec![])
            }
        }
    });

    let best = best
        .into_inner()
        .unwrap()
        .ok_or_else(|| anyhow::anyhow!("no successful trials"))?;
    Ok(SearchOutcome {
        history,
        best: best.sol,
        best_eval: best.eval,
        tuned_weights: best.tuned,
    })
}

/// Convenience: the incumbent-value curve for Fig. 4.
pub fn outcome_curve(outcome: &SearchOutcome) -> Vec<f64> {
    best_curve(&outcome.history)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn space_dims_per_format() {
        assert_eq!(space_for(FormatKind::MxInt, 18, 2.0, 8.0).dims(), 18);
        assert_eq!(space_for(FormatKind::Int, 18, 2.0, 8.0).dims(), 36);
        assert_eq!(space_for(FormatKind::Bl, 18, 2.0, 8.0).dims(), 18);
    }

    #[test]
    fn int_space_widths_at_least_3_bits() {
        let s = space_for(FormatKind::Int, 4, 2.0, 8.0);
        assert!(s.lo[..4].iter().all(|&l| l >= 3.0));
        assert!(s.lo[4..].iter().all(|&l| l == -2.0));
    }

    #[test]
    fn default_config_is_batched_and_auto_threaded() {
        let cfg = SearchConfig::default();
        assert!(cfg.batch > 1);
        assert_eq!(cfg.threads, 0, "0 must mean auto-detect");
        assert!(threads_from_env(cfg.threads) >= 1);
        assert_eq!(threads_from_env(3), 3);
    }
}
