//! `search` pass (Table 2, §4.3): resource-constrained mixed-precision
//! search. Orchestrates one of the [`crate::search`] algorithms over the
//! per-tensor precision space S' (= N^V for MXInt, N^2V for fixed point),
//! scoring each trial with the `evaluate` pass. Optionally interleaves
//! QAT fine-tune steps (small models, Fig. 6) — the "trainable IR" in
//! action.
//!
//! Trials are evaluated through the batched parallel driver
//! [`crate::search::run_batched_cached`]: `cfg.batch` proposals per
//! ask/tell round fan out over `cfg.threads` workers, with a memo cache
//! keyed on the *rounded* search vector (the exact quantization
//! [`QuantSolution::from_search_vector`] applies), so duplicate
//! proposals are never re-simulated. With a fixed seed the trial history
//! is identical for every thread count — see the batch-order convention
//! in the `search` module docs. [`run_search_cached`] accepts a
//! caller-owned (possibly disk-backed, see
//! [`crate::search::CacheStore`]) cache keyed by [`eval_scope`], which
//! is how `mase sweep` and the Fig. 4/6 benches amortize evaluations
//! across format/task combinations and across process runs.

use super::evaluate::{EvalResult, Evaluator};
use super::profile::ProfileData;
use super::quantize::QuantSolution;
use crate::data::Task;
use crate::formats::FormatKind;
use crate::obs::Registry;
use crate::runtime::{BackendKind, ExecBackend};
use crate::search::{
    best_curve, run_batched_traced, Algorithm, BatchOptions, CacheStats, EvalCache, LieStrategy,
    MemoKey, Space, Trial,
};
use crate::util::pool::threads_from_env;
use anyhow::Result;

#[derive(Debug, Clone)]
pub struct SearchConfig {
    pub algorithm: Algorithm,
    pub trials: usize,
    pub fmt: FormatKind,
    pub seed: u64,
    /// QAT fine-tune steps per trial (0 = PTQ).
    pub qat_steps: usize,
    pub qat_lr: f32,
    /// Bits range searched per tensor.
    pub bits_lo: f64,
    pub bits_hi: f64,
    /// Proposals evaluated concurrently per ask/tell round (1 = the
    /// serial cadence).
    pub batch: usize,
    /// Worker threads for trial evaluation; 0 = the `MASE_THREADS` env
    /// var, falling back to all cores minus one (see
    /// [`crate::util::pool::threads_from_env`]).
    pub threads: usize,
    /// Use TPE's mean-value constant lie instead of the worst-observed
    /// lie for batched proposals (see [`LieStrategy`]).
    pub tpe_mean_lie: bool,
}

impl Default for SearchConfig {
    fn default() -> Self {
        Self {
            algorithm: Algorithm::Tpe,
            trials: 64,
            fmt: FormatKind::MxInt,
            seed: 0,
            qat_steps: 0,
            qat_lr: 0.002,
            bits_lo: 2.0,
            bits_hi: 8.0,
            batch: 8,
            threads: 0,
            tpe_mean_lie: false,
        }
    }
}

#[derive(Debug, Clone)]
pub struct SearchOutcome {
    pub history: Vec<Trial>,
    pub best: QuantSolution,
    pub best_eval: EvalResult,
    /// Fine-tuned weights if QAT ran (else None).
    pub tuned_weights: Option<Vec<f32>>,
    /// Memo-cache activity during this search: hit/miss/insert deltas
    /// plus the cache's final entry count. `misses` is exactly the
    /// number of evaluator invocations the search paid for.
    pub cache: CacheStats,
}

/// The search space for a format family (paper §4.1's reduction: MXInt
/// searches V mantissa widths; fixed point searches 2V width+frac knobs).
pub fn space_for(fmt: FormatKind, num_qtensors: usize, lo: f64, hi: f64) -> Space {
    match fmt {
        FormatKind::Int => {
            let mut l = vec![lo.max(3.0); num_qtensors];
            let mut h = vec![hi; num_qtensors];
            l.extend(vec![-2.0; num_qtensors]); // frac offset from calibration
            h.extend(vec![2.0; num_qtensors]);
            Space::new(l, h)
        }
        _ => Space::uniform(num_qtensors, lo, hi),
    }
}

/// Scope string namespacing one evaluation context inside a
/// [`crate::search::CacheStore`]. Memoized values are only valid for the
/// exact objective that produced them, so every knob that changes what a
/// config scores — model, task, format, memo mode, the *effective* QAT
/// budget and learning rate, number of eval batches, pretrain budget,
/// the objective flavor ("hw" cost-aware vs "sw" accuracy-only), and the
/// execution backend that measured it (PJRT numerics and the packed CPU
/// interpreter are different oracles) — is part of the scope. Two runs
/// that differ in any of these read and write disjoint entry sets. The
/// learning rate only appears when QAT actually runs (`qat_steps > 0`);
/// it does not affect PTQ scoring.
///
/// `weights_hash` is the content hash of the `.mxa` packed-weight
/// artifact serving the run (see [`crate::packed::artifact`] and
/// [`ExecBackend::weights_hash`]); it appends a trailing `/mxa<hex>`
/// segment. Runs without an artifact (`None`) keep the historical scope
/// string unchanged, so existing on-disk caches stay valid.
#[allow(clippy::too_many_arguments)]
pub fn eval_scope(
    model: &str,
    task: Task,
    fmt: FormatKind,
    qat_steps: usize,
    qat_lr: f32,
    eval_batches: usize,
    pretrain_steps: usize,
    objective: &str,
    backend: BackendKind,
    weights_hash: Option<u64>,
) -> String {
    let qat = if qat_steps > 0 {
        format!("qat{qat_steps}-lr{qat_lr}")
    } else {
        "qat0".to_string()
    };
    let mut scope = format!(
        "{model}/{}/{}/{}/{qat}/eb{eval_batches}/ps{pretrain_steps}/{objective}/{}",
        task.name(),
        fmt.name(),
        MemoKey::Rounded.name(),
        backend.name(),
    );
    if let Some(h) = weights_hash {
        scope.push_str(&format!("/mxa{}", crate::util::hex16(h)));
    }
    scope
}

/// Run the full search for one (model, task, format) with a private,
/// run-local memo cache. See [`run_search_cached`] for the shared form.
pub fn run_search<B: ExecBackend>(
    ev: &Evaluator<B>,
    profile: &ProfileData,
    task: Task,
    cfg: &SearchConfig,
) -> Result<SearchOutcome> {
    run_search_cached(ev, profile, task, cfg, &EvalCache::new())
}

/// [`run_search`] against a caller-owned [`EvalCache`] — the persistent
/// cross-sweep path. The cache may be pre-seeded from disk (see
/// [`crate::search::CacheStore`]); configurations already present are
/// never re-simulated, and a fully warm cache makes the whole search
/// evaluator-free. The returned [`SearchOutcome::cache`] reports this
/// run's hit/miss/insert deltas.
///
/// The caller must hand the same cache only to searches whose objective
/// is identical (same model, task, format, QAT/eval/pretrain budgets and
/// objective flavor) — key by [`eval_scope`] when in doubt.
pub fn run_search_cached<B: ExecBackend>(
    ev: &Evaluator<B>,
    profile: &ProfileData,
    task: Task,
    cfg: &SearchConfig,
    cache: &EvalCache,
) -> Result<SearchOutcome> {
    run_search_traced(ev, profile, task, cfg, cache, Registry::none())
}

/// [`run_search_cached`] plus PR 8 observability: per-trial
/// `search/trial` spans tagged with memo status (via
/// [`run_batched_traced`]) and this run's [`CacheStats`] delta folded
/// into the registry as `search/cache` counters.
pub fn run_search_traced<B: ExecBackend>(
    ev: &Evaluator<B>,
    profile: &ProfileData,
    task: Task,
    cfg: &SearchConfig,
    cache: &EvalCache,
    rec: &Registry,
) -> Result<SearchOutcome> {
    let stats_before = cache.stats();
    let v = ev.meta.num_qtensors();
    let space = space_for(cfg.fmt, v, cfg.bits_lo, cfg.bits_hi);

    // Optional per-trial QAT: fine-tune a scratch copy of the weights on
    // the train split under the trial's quantization, then evaluate.
    // Fail fast if the backend cannot tune this (model, format) at all
    // (missing artifact on PJRT; no gradient path on the CPU interpreter).
    if cfg.qat_steps > 0 {
        ev.backend.qat_available(ev.meta, cfg.fmt)?;
    }
    let train_batches = if cfg.qat_steps > 0 {
        crate::data::batches(task, 0, cfg.qat_steps, ev.meta.batch, ev.meta.seq_len)
    } else {
        Vec::new()
    };

    // QAT fine-tune on a scratch copy — a pure function of the solution
    // (fixed train stream, no shared mutable state), so workers can call
    // it concurrently.
    let qat_tune = |sol: &QuantSolution| -> Option<Result<Vec<f32>>> {
        if cfg.qat_steps == 0 {
            return None;
        }
        let qcfg = sol.to_qconfig();
        Some(ev.backend.qat_tune(
            ev.meta,
            ev.weights,
            &train_batches,
            cfg.fmt,
            &qcfg,
            cfg.qat_lr,
        ))
    };

    // Running winner, tracked across workers. The tie-break on the
    // rounded key makes the final content a pure max over the set of
    // evaluated configurations — independent of worker arrival order,
    // preserving the determinism guarantee. Every distinct config passes
    // through the objective exactly once (run_batched memoizes
    // duplicates), so the winner's full EvalResult and QAT weights are
    // captured here without a second evaluation.
    struct BestTrial {
        value: f64,
        key: Vec<u64>,
        sol: QuantSolution,
        eval: EvalResult,
        tuned: Option<Vec<f32>>,
    }
    let best: std::sync::Mutex<Option<BestTrial>> = std::sync::Mutex::new(None);

    let opts = BatchOptions {
        batch: cfg.batch.max(1),
        threads: threads_from_env(cfg.threads),
        memo: MemoKey::Rounded,
        tpe_lie: if cfg.tpe_mean_lie { LieStrategy::Mean } else { LieStrategy::Min },
    };
    let (alg, seed, trials) = (cfg.algorithm, cfg.seed, cfg.trials);
    let history = run_batched_traced(alg, space, seed, trials, &opts, cache, rec, |x| {
        let sol = QuantSolution::from_search_vector(cfg.fmt, x, ev.meta, profile);
        let tuned = match qat_tune(&sol) {
            Some(Ok(w)) => Some(w),
            Some(Err(e)) => {
                eprintln!("trial failed: {e:#}");
                return (f64::NEG_INFINITY, vec![]);
            }
            None => None,
        };
        let result = match &tuned {
            Some(w) => ev.evaluate_with_weights(&sol, w),
            None => ev.evaluate(&sol),
        };
        match result {
            Ok(r) => {
                if r.value.is_finite() {
                    let key = MemoKey::Rounded.key(x);
                    let mut b = best.lock().unwrap();
                    let better = match &*b {
                        None => true,
                        Some(cur) => {
                            r.value > cur.value || (r.value == cur.value && key < cur.key)
                        }
                    };
                    if better {
                        *b = Some(BestTrial {
                            value: r.value,
                            key,
                            sol,
                            eval: r.clone(),
                            tuned,
                        });
                    }
                }
                (r.value, r.objectives)
            }
            Err(e) => {
                eprintln!("trial failed: {e:#}");
                (f64::NEG_INFINITY, vec![])
            }
        }
    });

    // Winner selection scans the HISTORY, not just the configs this run
    // evaluated: with a pre-seeded cache ([`run_search_cached`]) the best
    // trial may have been served from disk without ever reaching the
    // objective closure above. Ordering matches the in-closure tracker —
    // max value, ties broken by the smaller rounded key — so cold runs
    // pick the identical winner they always did.
    let mut winner: Option<(f64, Vec<u64>, usize)> = None;
    for (i, t) in history.iter().enumerate() {
        if !t.value.is_finite() {
            continue;
        }
        let key = MemoKey::Rounded.key(&t.x);
        let better = match &winner {
            None => true,
            Some((v, k, _)) => t.value > *v || (t.value == *v && key < *k),
        };
        if better {
            winner = Some((t.value, key, i));
        }
    }
    let (win_value, win_key, win_idx) =
        winner.ok_or_else(|| anyhow::anyhow!("no successful trials"))?;

    let captured = best.into_inner().unwrap();
    let (best_sol, best_eval, tuned_weights) = match captured {
        // The winner passed through the objective this run: use the full
        // EvalResult (and QAT weights) captured there.
        Some(b) if b.value == win_value && b.key == win_key => (b.sol, b.eval, b.tuned),
        // The winner came out of the memo cache. Rebuild what the cache
        // carries (value + objective components, acc is component 0) plus
        // the deterministic hardware half — deliberately WITHOUT calling
        // the evaluator, so a fully warm search stays evaluator-free.
        // The PJRT-side loss/perplexity are not memoized and read NaN;
        // QAT-tuned weights cannot be reconstructed either.
        _ => {
            let t = &history[win_idx];
            let sol = QuantSolution::from_search_vector(cfg.fmt, &t.x, ev.meta, profile);
            let (dp, avg_bits, _g) = ev.hardware(&sol)?;
            let eval = EvalResult {
                accuracy: t.objectives.first().copied().unwrap_or(f64::NAN),
                mean_loss: f64::NAN,
                perplexity: f64::NAN,
                avg_bits,
                design: dp,
                value: win_value,
                objectives: t.objectives.clone(),
            };
            (sol, eval, None)
        }
    };
    let delta = cache.stats().delta(&stats_before);
    delta.record_to(rec, "search/cache");
    Ok(SearchOutcome {
        history,
        best: best_sol,
        best_eval,
        tuned_weights,
        cache: delta,
    })
}

/// Convenience: the incumbent-value curve for Fig. 4.
pub fn outcome_curve(outcome: &SearchOutcome) -> Vec<f64> {
    best_curve(&outcome.history)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn space_dims_per_format() {
        assert_eq!(space_for(FormatKind::MxInt, 18, 2.0, 8.0).dims(), 18);
        assert_eq!(space_for(FormatKind::Int, 18, 2.0, 8.0).dims(), 36);
        assert_eq!(space_for(FormatKind::Bl, 18, 2.0, 8.0).dims(), 18);
    }

    #[test]
    fn int_space_widths_at_least_3_bits() {
        let s = space_for(FormatKind::Int, 4, 2.0, 8.0);
        assert!(s.lo[..4].iter().all(|&l| l >= 3.0));
        assert!(s.lo[4..].iter().all(|&l| l == -2.0));
    }

    #[test]
    fn eval_scope_separates_contexts() {
        use BackendKind::{Cpu, Pjrt};
        let lr = 0.002;
        let a =
            eval_scope("opt-125m-sim", Task::Sst2, FormatKind::MxInt, 0, lr, 4, 220, "hw", Pjrt, None);
        assert_eq!(a, "opt-125m-sim/sst2/mxint/rounded/qat0/eb4/ps220/hw/pjrt");
        // every objective-changing knob must change the scope
        for b in [
            eval_scope("opt-350m-sim", Task::Sst2, FormatKind::MxInt, 0, lr, 4, 220, "hw", Pjrt, None),
            eval_scope("opt-125m-sim", Task::Qqp, FormatKind::MxInt, 0, lr, 4, 220, "hw", Pjrt, None),
            eval_scope("opt-125m-sim", Task::Sst2, FormatKind::Int, 0, lr, 4, 220, "hw", Pjrt, None),
            eval_scope("opt-125m-sim", Task::Sst2, FormatKind::MxInt, 2, lr, 4, 220, "hw", Pjrt, None),
            eval_scope("opt-125m-sim", Task::Sst2, FormatKind::MxInt, 0, lr, 3, 220, "hw", Pjrt, None),
            eval_scope("opt-125m-sim", Task::Sst2, FormatKind::MxInt, 0, lr, 4, 100, "hw", Pjrt, None),
            eval_scope("opt-125m-sim", Task::Sst2, FormatKind::MxInt, 0, lr, 4, 220, "sw", Pjrt, None),
            eval_scope("opt-125m-sim", Task::Sst2, FormatKind::MxInt, 0, lr, 4, 220, "hw", Cpu, None),
            eval_scope("opt-125m-sim", Task::Sst2, FormatKind::MxInt, 0, lr, 4, 220, "hw", Pjrt, Some(7)),
        ] {
            assert_ne!(a, b);
        }
        // the backend identity is part of the scope: PJRT-measured and
        // CPU-interpreter-measured objectives never share entries
        let c =
            eval_scope("opt-125m-sim", Task::Sst2, FormatKind::MxInt, 0, lr, 4, 220, "hw", Cpu, None);
        assert_eq!(c, "opt-125m-sim/sst2/mxint/rounded/qat0/eb4/ps220/hw/cpu");
        // the QAT learning rate matters exactly when QAT runs
        let q1 = eval_scope("m", Task::Sst2, FormatKind::MxInt, 2, 0.002, 4, 220, "hw", Pjrt, None);
        let q2 = eval_scope("m", Task::Sst2, FormatKind::MxInt, 2, 0.01, 4, 220, "hw", Pjrt, None);
        assert_ne!(q1, q2, "differing QAT lr must not share entries");
        let p1 = eval_scope("m", Task::Sst2, FormatKind::MxInt, 0, 0.002, 4, 220, "hw", Pjrt, None);
        let p2 = eval_scope("m", Task::Sst2, FormatKind::MxInt, 0, 0.01, 4, 220, "hw", Pjrt, None);
        assert_eq!(p1, p2, "lr is irrelevant under PTQ");
        // artifact-backed runs get their own namespace; the hash is the
        // PR 2 fixed-width hex convention
        let m = eval_scope("m", Task::Sst2, FormatKind::MxInt, 0, lr, 4, 220, "hw", Cpu, Some(0xAB));
        assert_eq!(m, "m/sst2/mxint/rounded/qat0/eb4/ps220/hw/cpu/mxa00000000000000ab");
        assert_ne!(
            m,
            eval_scope("m", Task::Sst2, FormatKind::MxInt, 0, lr, 4, 220, "hw", Cpu, Some(0xAC))
        );
    }

    #[test]
    fn default_config_is_batched_and_auto_threaded() {
        let cfg = SearchConfig::default();
        assert!(cfg.batch > 1);
        assert_eq!(cfg.threads, 0, "0 must mean auto-detect");
        assert!(threads_from_env(cfg.threads) >= 1);
        assert_eq!(threads_from_env(3), 3);
    }
}
