//! `parallelize` pass (Table 2, §4.2): resource-constrained tile-size
//! allocation. Greedy throughput balancing: start every operator at
//! minimal parallelism, then repeatedly double the tile of the current
//! bottleneck (the op with the most cycles per inference) while the LUT
//! budget holds. This converges to the balanced pipeline the paper
//! describes ("a set of tile sizes ... for balanced throughput between
//! operators"), and fills in all hardware attributes of Fig. 2c.

use crate::formats::Precision;
use crate::hw::area::op_area_luts;
use crate::hw::memory::{bandwidth_cap, offchip_bits_per_inference, plan};
use crate::hw::throughput::{op_cycles_streamed, pipeline_latency_cycles, pipeline_throughput};
use crate::hw::Device;
use crate::ir::{Graph, OpKind, StreamOrder};

/// Evaluated hardware design point (the regression model's output).
#[derive(Debug, Clone)]
pub struct DesignPoint {
    pub area_luts: f64,
    pub throughput: f64,
    pub latency_cycles: f64,
    pub offchip_bits: f64,
    pub utilization: f64,
}

impl DesignPoint {
    /// Area efficiency: throughput per LUT (the paper's Figs. 5/7 metric,
    /// reported relative to the int8 design).
    pub fn area_efficiency(&self) -> f64 {
        if self.area_luts <= 0.0 {
            0.0
        } else {
            self.throughput / self.area_luts
        }
    }
}

/// The quantized-GEMM precision an op's datapath must support: the wider
/// of its weight and (first) activation qtensor precisions.
fn op_precision(g: &Graph, op: &crate::ir::Operation) -> Precision {
    let mut p = Precision::new(2.0, 0.0);
    for &w in &op.params {
        let t = &g.value(w).ty;
        if t.precision.bits > p.bits {
            p = t.precision;
        }
    }
    for &a in &op.args {
        let t = &g.value(a).ty;
        if t.format.is_block_format() || t.format == crate::formats::FormatKind::Int {
            if t.precision.bits > p.bits {
                p = t.precision;
            }
        }
    }
    p
}

fn design_format(g: &Graph) -> crate::formats::FormatKind {
    g.values
        .iter()
        .map(|v| v.ty.format)
        .find(|f| *f != crate::formats::FormatKind::Fp32)
        .unwrap_or(crate::formats::FormatKind::Fp32)
}

fn total_area(g: &Graph) -> f64 {
    g.ops.iter().map(|o| o.attrs.area_luts).sum()
}

fn recompute_op(g: &mut Graph, i: usize, fmt: crate::formats::FormatKind, channel_bits: u64) {
    let op = &g.ops[i];
    let tile = op.results.first().map(|&r| g.value(r).attrs.tile).unwrap_or((1, 1));
    let p = op_precision(g, op);
    let area = op_area_luts(op.kind, fmt, p, tile);
    // Bandwidth-aware: an op behind an under-provisioned channel is
    // slowed to its transfer rate (beat model), so the greedy balancer —
    // and through it the search objective — sees channel serialization.
    let cycles = op_cycles_streamed(g, op, tile, channel_bits);
    let op = &mut g.ops[i];
    op.attrs.area_luts = area;
    op.attrs.ii_cycles = cycles;
    op.attrs.hw_ip = format!("{}_{}", fmt.name(), op.kind.name());
}

/// Run the pass: annotate tiles/areas/IIs on `g`, return the design point.
/// `budget_frac` is the fraction of device LUTs the design may use.
pub fn parallelize(g: &mut Graph, device: &Device, budget_frac: f64) -> DesignPoint {
    let fmt = design_format(g);
    let budget = device.luts * budget_frac;

    // init: minimal tiles, mark stream orders for the dataflow-specific ops
    for i in 0..g.ops.len() {
        let kind = g.ops[i].kind;
        if let Some(&r) = g.ops[i].results.first() {
            let v = g.value_mut(r);
            v.attrs.tile = if kind.is_gemm() { (2, 2) } else { (1, 2) };
            v.attrs.order =
                if kind == OpKind::Transpose { StreamOrder::ColMajor } else { StreamOrder::RowMajor };
        }
        recompute_op(g, i, fmt, device.channel_bits);
    }

    // greedy: double the bottleneck op's tile while budget allows
    loop {
        let (mut worst, mut worst_cycles) = (usize::MAX, 0.0f64);
        for (i, op) in g.ops.iter().enumerate() {
            if op.attrs.ii_cycles > worst_cycles {
                worst_cycles = op.attrs.ii_cycles;
                worst = i;
            }
        }
        if worst == usize::MAX || worst_cycles <= 1.0 {
            break;
        }
        let r = match g.ops[worst].results.first() {
            Some(&r) => r,
            None => break,
        };
        let old_tile = g.value(r).attrs.tile;
        // grow the smaller dimension first (keeps tiles near-square, and
        // within the output tensor bounds)
        let out_shape = g.value(r).ty.shape.clone();
        let max_r = out_shape.get(out_shape.len().saturating_sub(2)).copied().unwrap_or(1);
        let max_c = out_shape.last().copied().unwrap_or(1);
        let new_tile = if old_tile.0 <= old_tile.1 && old_tile.0 * 2 <= max_r.max(2) {
            (old_tile.0 * 2, old_tile.1)
        } else if old_tile.1 * 2 <= max_c.max(2) {
            (old_tile.0, old_tile.1 * 2)
        } else if old_tile.0 * 2 <= max_r.max(2) {
            (old_tile.0 * 2, old_tile.1)
        } else {
            break; // bottleneck already at full parallelism
        };
        g.value_mut(r).attrs.tile = new_tile;
        recompute_op(g, worst, fmt, device.channel_bits);
        if total_area(g) > budget {
            // revert and stop
            g.value_mut(r).attrs.tile = old_tile;
            recompute_op(g, worst, fmt, device.channel_bits);
            break;
        }
        if g.ops[worst].attrs.ii_cycles >= worst_cycles {
            // Doubling the bottleneck's lanes bought nothing: the op is
            // channel-bound (beats grow with the tile payload as fast as
            // compute shrinks). Revert — spending area here is waste the
            // §4.2 balancer should leave to other ops — and stop rather
            // than loop on an unimprovable bottleneck.
            g.value_mut(r).attrs.tile = old_tile;
            recompute_op(g, worst, fmt, device.channel_bits);
            break;
        }
    }

    // fill edge throughputs (elements/cycle) for Fig. 2c reporting
    for i in 0..g.ops.len() {
        if let Some(&r) = g.ops[i].results.first() {
            let cycles = g.ops[i].attrs.ii_cycles.max(1.0);
            let elems = g.value(r).ty.elements() as f64;
            g.value_mut(r).attrs.throughput = elems / cycles;
        }
    }

    let placements = plan(g, device);
    let offchip = offchip_bits_per_inference(&placements);
    let thr = pipeline_throughput(g, device).min(bandwidth_cap(&placements, device));
    DesignPoint {
        area_luts: total_area(g),
        throughput: thr,
        latency_cycles: pipeline_latency_cycles(g, device),
        offchip_bits: offchip,
        utilization: total_area(g) / device.luts,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::FormatKind;
    use crate::frontend::{build_graph, manifest::ModelMeta};
    use crate::passes::{profile::ProfileData, QuantSolution};

    fn quantized_graph(bits: f32) -> Graph {
        let m = ModelMeta::synthetic("t", 2, 32, 2, 512, 32, 4, "classifier", 64);
        let p = ProfileData::uniform(&m, 4.0);
        let mut g = build_graph(&m);
        QuantSolution::uniform(FormatKind::MxInt, bits, &m, &p).apply(&mut g);
        g
    }

    #[test]
    fn respects_budget() {
        let mut g = quantized_graph(7.0);
        let d = Device::u250();
        let dp = parallelize(&mut g, &d, 0.5);
        assert!(dp.area_luts <= d.luts * 0.5 * 1.001, "{}", dp.area_luts);
        assert!(dp.throughput > 0.0);
    }

    #[test]
    fn more_budget_more_throughput() {
        let d = Device::u250();
        let mut g1 = quantized_graph(7.0);
        let t1 = parallelize(&mut g1, &d, 0.05).throughput;
        let mut g2 = quantized_graph(7.0);
        let t2 = parallelize(&mut g2, &d, 0.8).throughput;
        assert!(t2 > t1, "{t1} vs {t2}");
    }

    #[test]
    fn lower_precision_gives_better_area_efficiency() {
        // Same budget: 4-bit mantissas buy more parallel lanes than 7-bit.
        let d = Device::u250();
        let mut g_lo = quantized_graph(3.0);
        let mut g_hi = quantized_graph(7.0);
        let dp_lo = parallelize(&mut g_lo, &d, 0.3);
        let dp_hi = parallelize(&mut g_hi, &d, 0.3);
        assert!(
            dp_lo.area_efficiency() > dp_hi.area_efficiency(),
            "lo {} hi {}",
            dp_lo.area_efficiency(),
            dp_hi.area_efficiency()
        );
    }

    #[test]
    fn annotates_hw_attributes() {
        let mut g = quantized_graph(5.0);
        parallelize(&mut g, &Device::u250(), 0.3);
        for op in &g.ops {
            assert!(!op.attrs.hw_ip.is_empty());
        }
        // transpose results stream column-major (Fig. 1d)
        let t = g.ops.iter().find(|o| o.kind == OpKind::Transpose).unwrap();
        assert_eq!(g.value(t.results[0]).attrs.order, StreamOrder::ColMajor);
    }

    #[test]
    fn pipeline_is_roughly_balanced() {
        let mut g = quantized_graph(5.0);
        parallelize(&mut g, &Device::u250(), 0.5);
        let cycles: Vec<f64> =
            g.ops.iter().filter(|o| o.attrs.ii_cycles > 0.0).map(|o| o.attrs.ii_cycles).collect();
        let max = cycles.iter().cloned().fold(0.0, f64::max);
        let nontrivial = cycles.iter().filter(|&&c| c > max / 100.0).count();
        assert!(nontrivial >= 2, "degenerate balance: {cycles:?}");
    }
}
