//! `emit` pass (Table 2): translate the fully-annotated MASE IR into a
//! dataflow hardware design in SystemVerilog. Direct translation, no
//! analysis — every hardware parameter is already on the IR (paper §3.1
//! step 5). Writes one file per operator template plus the top-level.
//!
//! Since PR 6 the pass is gated: every emitted design runs through
//! [`crate::check::check_design`] (the real SV analyzer plus the
//! cross-layer bitwidth contracts) and error-level diagnostics abort
//! the emit before any file is written — the compiler cannot ship
//! SystemVerilog its own checker rejects.

use crate::emit::verilog::{emit_design, EmittedDesign};
use crate::ir::Graph;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Emit the design and write it under `out_dir`. Returns (files, total
/// SV line count) — the "Code size" column of Table 3. Fails (writing
/// nothing) if the static checker finds error-level diagnostics.
pub fn emit_to_dir(g: &Graph, out_dir: &Path) -> Result<(EmittedDesign, usize)> {
    let design = emit_design(g);
    let report = crate::check::check_design(&design, g, crate::hw::DEFAULT_CHANNEL_BITS);
    if report.has_errors() {
        bail!("emitted design failed static checks:\n{}", report.render());
    }
    std::fs::create_dir_all(out_dir)
        .with_context(|| format!("creating {}", out_dir.display()))?;
    let mut total_lines = 0;
    for (name, text) in &design.files {
        total_lines += text.lines().count();
        std::fs::write(out_dir.join(name), text)
            .with_context(|| format!("writing {name}"))?;
    }
    Ok((design, total_lines))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::FormatKind;
    use crate::frontend::{build_graph, manifest::ModelMeta};
    use crate::hw::Device;
    use crate::passes::{parallelize, profile::ProfileData, QuantSolution};

    #[test]
    fn emits_files_to_directory() {
        let m = ModelMeta::synthetic("t", 1, 32, 2, 512, 32, 4, "classifier", 64);
        let p = ProfileData::uniform(&m, 4.0);
        let mut g = build_graph(&m);
        QuantSolution::uniform(FormatKind::MxInt, 5.0, &m, &p).apply(&mut g);
        parallelize(&mut g, &Device::u250(), 0.2);
        let dir = std::env::temp_dir().join("mase_emit_test");
        let _ = std::fs::remove_dir_all(&dir);
        let (design, lines) = emit_to_dir(&g, &dir).unwrap();
        assert!(design.files.len() > 3);
        assert!(lines > 100);
        assert!(dir.join("top.sv").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
