//! `profile` pass (Table 2): run the unquantized model over calibration
//! batches (through either execution backend) and collect per-qtensor
//! value statistics — the data behind Fig. 1a (activation variance
//! exploding in deeper layers) and the calibration source for
//! fixed-point fraction widths.

use crate::data::Batch;
use crate::frontend::ModelMeta;
use crate::runtime::ExecBackend;
use anyhow::Result;

/// Per-qtensor statistics, averaged over calibration batches.
#[derive(Debug, Clone)]
pub struct ProfileData {
    pub names: Vec<String>,
    pub variance: Vec<f64>,
    pub absmax: Vec<f64>,
    pub absmean: Vec<f64>,
}

impl ProfileData {
    /// Uniform fallback when no runtime/batches are available (tests).
    pub fn uniform(meta: &ModelMeta, absmax: f64) -> Self {
        let v = meta.num_qtensors();
        ProfileData {
            names: meta.qtensors.clone(),
            variance: vec![1.0; v],
            absmax: vec![absmax; v],
            absmean: vec![absmax / 3.0; v],
        }
    }

    /// Fig. 1a's headline number: max variance ratio across tensors.
    pub fn variance_spread(&self) -> f64 {
        let mx = self.variance.iter().cloned().fold(f64::MIN, f64::max);
        let mn = self.variance.iter().cloned().fold(f64::MAX, f64::min).max(1e-30);
        mx / mn
    }
}

/// Run the backend's profile kernel over `batches` and average the
/// statistics (variance/absmean averaged, absmax maxed across batches).
pub fn profile_model<B: ExecBackend>(
    backend: &B,
    meta: &ModelMeta,
    weights: &[f32],
    batches: &[Batch],
) -> Result<ProfileData> {
    let v = meta.num_qtensors();
    let mut variance = vec![0.0f64; v];
    let mut absmax = vec![0.0f64; v];
    let mut absmean = vec![0.0f64; v];
    for b in batches {
        let stats = backend.profile_batch(meta, weights, b)?; // [V] rows of (var, max, mean)
        for i in 0..v {
            variance[i] += stats[i][0] as f64;
            absmax[i] = absmax[i].max(stats[i][1] as f64);
            absmean[i] += stats[i][2] as f64;
        }
    }
    let n = batches.len().max(1) as f64;
    for i in 0..v {
        variance[i] /= n;
        absmean[i] /= n;
    }
    Ok(ProfileData { names: meta.qtensors.clone(), variance, absmax, absmean })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::manifest::ModelMeta;

    #[test]
    fn uniform_profile_shape() {
        let m = ModelMeta::synthetic("t", 2, 32, 2, 512, 32, 4, "classifier", 64);
        let p = ProfileData::uniform(&m, 4.0);
        assert_eq!(p.names.len(), m.num_qtensors());
        assert_eq!(p.absmax[0], 4.0);
        assert!((p.variance_spread() - 1.0).abs() < 1e-12);
    }
}
