//! The MASE pass pipeline (paper Table 2): `profile`, `quantize`,
//! `parallelize`, `evaluate`, `search`, `emit`, orchestrated by a
//! [`PassManager`] that records per-pass wall-clock (Table 4).
//!
//! All passes are *type-independent*: they read the format/precision off
//! the IR values and dispatch through `formats`/`hw`, which is what lets a
//! new data format plug in with only a software emulator (L2) and a
//! hardware template + cost model (`hw`, `emit`) — the paper's
//! orchestration claim (§3.2, Fig. 3).

pub mod emit_pass;
pub mod evaluate;
pub mod parallelize;
pub mod profile;
pub mod quantize;
pub mod search_pass;

pub use evaluate::{EvalResult, Evaluator, Objective};
pub use parallelize::{parallelize, DesignPoint};
pub use profile::{profile_model, ProfileData};
pub use quantize::QuantSolution;
pub use search_pass::{
    eval_scope, run_search, run_search_cached, run_search_traced, SearchConfig, SearchOutcome,
};

use std::collections::BTreeMap;
use std::time::Instant;

/// PR 6 pass-boundary gate: run the IR verifier after a transforming
/// pass and fail the flow with *all* findings listed, instead of letting
/// a malformed graph flow into downstream cost models and the emitter.
pub fn verify_boundary(g: &crate::ir::Graph, boundary: &str) -> anyhow::Result<()> {
    let errs = crate::ir::verify(g);
    if errs.is_empty() {
        return Ok(());
    }
    let listing =
        errs.iter().map(|e| format!("  - {e}")).collect::<Vec<_>>().join("\n");
    anyhow::bail!(
        "IR verification failed after `{boundary}` ({} finding(s)):\n{listing}",
        errs.len()
    )
}

/// Wall-clock bookkeeping per pass — regenerates Table 4's runtime
/// breakdown. With a recorder attached ([`PassManager::attach`]) every
/// pass boundary additionally records a `pass/<name>` span in the PR 8
/// trace registry.
#[derive(Debug, Default, Clone)]
pub struct PassManager {
    /// pass name -> (total seconds, invocations)
    pub timings: BTreeMap<String, (f64, u64)>,
    /// PR 8 observability: pass-boundary spans land here when set.
    pub recorder: Option<std::sync::Arc<crate::obs::Registry>>,
}

impl PassManager {
    pub fn new() -> Self {
        Self::default()
    }

    /// Attach a trace registry: subsequent [`run`](Self::run) calls
    /// record `pass/<name>` spans (pass boundaries are single-threaded
    /// orchestration points, so the event stream stays deterministic).
    pub fn attach(&mut self, recorder: std::sync::Arc<crate::obs::Registry>) {
        self.recorder = Some(recorder);
    }

    /// Run `f` as pass `name`, recording its duration.
    pub fn run<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let span = self.recorder.as_ref().map(|r| r.span(&format!("pass/{name}")));
        let out = f();
        drop(span);
        let dt = t0.elapsed().as_secs_f64();
        let e = self.timings.entry(name.to_string()).or_insert((0.0, 0));
        e.0 += dt;
        e.1 += 1;
        out
    }

    /// (total seconds, count) for a pass.
    pub fn stat(&self, name: &str) -> (f64, u64) {
        self.timings.get(name).copied().unwrap_or((0.0, 0))
    }

    /// Render the Table 4 style breakdown.
    pub fn report(&self) -> String {
        let mut t = crate::util::Table::new(vec!["pass", "total_s", "calls", "per_call_s"]);
        for (name, (secs, calls)) in &self.timings {
            t.row(vec![
                name.clone(),
                format!("{secs:.3}"),
                calls.to_string(),
                format!("{:.3}", secs / *calls as f64),
            ]);
        }
        t.render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn verify_boundary_lists_all_findings() {
        let mut g = crate::ir::Graph::new("bad");
        g.new_value("dangling", crate::ir::TensorType::fp32(vec![4]), None);
        // no outputs + orphan value -> two findings, both in the message
        let msg = format!("{}", verify_boundary(&g, "quantize").unwrap_err());
        assert!(msg.contains("after `quantize`"), "{msg}");
        assert!(msg.contains("2 finding(s)"), "{msg}");
        assert!(msg.contains("dangling"), "{msg}");
        assert!(msg.contains("no outputs"), "{msg}");
    }

    #[test]
    fn attached_recorder_sees_pass_spans() {
        let mut pm = PassManager::new();
        let reg = std::sync::Arc::new(crate::obs::Registry::new());
        pm.attach(reg.clone());
        pm.run("quantize", || ());
        pm.run("emit", || ());
        let ev = reg.sorted_events();
        assert_eq!(ev.len(), 2);
        assert_eq!(ev[0].path, "pass/emit");
        assert_eq!(ev[1].path, "pass/quantize");
        assert_eq!(pm.stat("quantize").1, 1);
    }

    #[test]
    fn records_timings() {
        let mut pm = PassManager::new();
        let v = pm.run("quantize", || 42);
        assert_eq!(v, 42);
        pm.run("quantize", || ());
        let (secs, calls) = pm.stat("quantize");
        assert_eq!(calls, 2);
        assert!(secs >= 0.0);
        assert!(pm.report().contains("quantize"));
    }
}
