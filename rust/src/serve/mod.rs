//! `mase serve` — an HTTP inference service over the CPU decode engine
//! (PR 9). Three sub-modules, strictly layered:
//!
//!  * [`http`]: hand-rolled HTTP/1.1 request reader / response writer on
//!    `std::net` (offline vendored environment — no tokio/axum/hyper);
//!  * [`protocol`]: JSON request validation and response rendering
//!    through the depth-limited [`crate::util::json`] parser;
//!  * [`scheduler`]: the continuous-batching core — a lane-partitioned
//!    [`crate::runtime::decode::Decoder`] group that admits and retires
//!    requests *between* position steps ([`BatchEngine`]), a bounded
//!    FIFO [`RequestQueue`] with 429/503 backpressure, and the
//!    single-threaded [`run_scheduler`] loop.
//!
//! This module is the assembly: route dispatch ([`handle_request`]) and
//! the blocking [`serve`] entry point `mase serve` calls — one listener,
//! a small pool of connection-handler threads, one scheduler thread.
//!
//! Routes: `POST /v1/generate` (decode), `GET /healthz` (static service
//! facts), `GET /metrics` (the [`TraceSummary`] rendering of the
//! `serve/*` spans and counters).
//!
//! Determinism contract: given a fixed seed and a fixed admission
//! order, the tokens served are bit-identical to running each request
//! alone through [`crate::runtime::decode::Decoder::generate`] — see
//! the `scheduler` module doc for the lane argument, and
//! `tests/serve_batching.rs` for the assertion.
//!
//! Shutdown: the process has no signal handler (no `libc` in the
//! vendored set); SIGTERM terminates it via the default disposition,
//! which is fine for a `connection: close` service with no durable
//! state. The CI smoke test drives exactly that path.

pub mod http;
pub mod protocol;
pub mod scheduler;

pub use protocol::{GenRequest, Reply, ServeError, ServeInfo};
pub use scheduler::{run_scheduler, BatchEngine, Completion, RequestQueue, ServeConfig};

use crate::obs::{Registry, TraceSummary};
use anyhow::{Context, Result};
use std::io::{BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::time::Duration;

/// Everything [`serve`] needs beyond the model itself.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Port to bind on 127.0.0.1 (0 = ephemeral, printed on stdout).
    pub port: u16,
    /// Connection-handler threads (each owns one connection at a time).
    pub http_workers: usize,
    pub cfg: ServeConfig,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions { port: 0, http_workers: 4, cfg: ServeConfig::default() }
    }
}

/// Dispatch one parsed request. Pure request → response (no I/O), so
/// the unit tests cover routing without sockets.
pub fn handle_request(
    req: &http::Request,
    queue: &RequestQueue,
    reg: &Registry,
    info: &ServeInfo,
    default_max_tokens: usize,
    reply_timeout: Duration,
) -> http::Response {
    reg.counter("serve/http", "requests", 1);
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => http::Response::json(200, protocol::render_health(info)),
        ("GET", "/metrics") => {
            let body = TraceSummary::from_registry(reg).render();
            let body = if body.is_empty() {
                "== trace summary ==\n(no events)\n".to_string()
            } else {
                body
            };
            http::Response::text(200, body)
        }
        ("POST", "/v1/generate") => {
            let body = match std::str::from_utf8(&req.body) {
                Ok(s) => s,
                Err(_) => {
                    let e = ServeError::BadRequest("body is not valid UTF-8".into());
                    return http::Response::json(e.status(), protocol::render_error(&e));
                }
            };
            let gen = match protocol::parse_generate(body, info, default_max_tokens) {
                Ok(g) => g,
                Err(e) => return http::Response::json(e.status(), protocol::render_error(&e)),
            };
            let rx = match queue.submit(gen) {
                Ok(rx) => rx,
                Err(e) => {
                    if matches!(e, ServeError::QueueFull { .. }) {
                        reg.counter("serve/http", "queue_full_429", 1);
                    }
                    return http::Response::json(e.status(), protocol::render_error(&e));
                }
            };
            match rx.recv_timeout(reply_timeout) {
                Ok(Ok(reply)) => http::Response::json(200, protocol::render_reply(info, &reply)),
                Ok(Err(e)) => http::Response::json(e.status(), protocol::render_error(&e)),
                Err(_) => {
                    let e = ServeError::Internal("timed out waiting for the scheduler".into());
                    http::Response::json(e.status(), protocol::render_error(&e))
                }
            }
        }
        (_, "/v1/generate") | (_, "/healthz") | (_, "/metrics") => http::Response::json(
            405,
            protocol::render_status_error(405, &format!("method {} not allowed here", req.method)),
        ),
        (_, p) => http::Response::json(
            404,
            protocol::render_status_error(404, &format!("no route for '{p}'")),
        ),
    }
}

fn handle_connection(
    stream: TcpStream,
    queue: &RequestQueue,
    reg: &Registry,
    info: &ServeInfo,
    default_max_tokens: usize,
    reply_timeout: Duration,
) {
    // socket timeouts bound a stalled client; the reply wait is bounded
    // separately, so give the write side the same generous ceiling
    let _ = stream.set_read_timeout(Some(Duration::from_secs(10)));
    let _ = stream.set_write_timeout(Some(reply_timeout + Duration::from_secs(10)));
    let mut reader = BufReader::new(stream);
    let resp = match http::read_request(&mut reader) {
        Ok(Some(req)) => handle_request(&req, queue, reg, info, default_max_tokens, reply_timeout),
        Ok(None) => return, // client connected and went away
        Err(http::HttpError::Bad { status, msg }) => {
            http::Response::json(status, protocol::render_status_error(status, &msg))
        }
        Err(http::HttpError::Io(_)) => return, // transport died; nothing to say
    };
    let mut stream = reader.into_inner();
    let _ = http::write_response(&mut stream, &resp);
}

/// Run the service until the process is terminated: bind, print the
/// address (stdout, flushed — the CI smoke test parses it), then serve.
///
/// Threads: `http_workers` connection handlers all blocking in
/// `accept()` on the shared listener, plus one scheduler thread driving
/// the [`BatchEngine`]. Handler threads never touch the engine — they
/// talk to the scheduler only through the [`RequestQueue`] and each
/// request's reply channel, which is what makes the decode path
/// single-threaded and deterministic.
pub fn serve(
    engine: &mut BatchEngine,
    info: &ServeInfo,
    opts: &ServeOptions,
    reg: &Registry,
) -> Result<()> {
    let listener = TcpListener::bind(("127.0.0.1", opts.port))
        .with_context(|| format!("binding 127.0.0.1:{}", opts.port))?;
    let addr = listener.local_addr()?;
    let queue = RequestQueue::new(opts.cfg.queue_cap, opts.cfg.queue_timeout_ms);
    let default_max_tokens = opts.cfg.default_max_tokens;
    // admitted work is bounded (seq_len positions/lane), so a reply not
    // arriving within queue-timeout + a wide decode allowance is a bug
    let reply_timeout = Duration::from_millis(opts.cfg.queue_timeout_ms) + Duration::from_secs(120);
    println!(
        "mase serve: listening on http://{addr} (model {}, fmt {}, {} lanes x width {})",
        info.model, info.fmt, info.lanes, info.width
    );
    std::io::stdout().flush().ok();
    std::thread::scope(|s| {
        s.spawn(|| run_scheduler(engine, &queue, reg));
        for _ in 0..opts.http_workers.max(1) {
            s.spawn(|| loop {
                match listener.accept() {
                    Ok((stream, _)) => handle_connection(
                        stream,
                        &queue,
                        reg,
                        info,
                        default_max_tokens,
                        reply_timeout,
                    ),
                    Err(_) => std::thread::sleep(Duration::from_millis(10)),
                }
            });
        }
    });
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn info() -> ServeInfo {
        ServeInfo {
            model: "toy-lm".into(),
            fmt: "fp32".into(),
            bits: 32.0,
            vocab: 512,
            seq_len: 32,
            lanes: 2,
            width: 1,
        }
    }

    fn get(path: &str) -> http::Request {
        http::Request {
            method: "GET".into(),
            path: path.into(),
            headers: vec![],
            body: vec![],
        }
    }

    #[test]
    fn routes_health_and_metrics() {
        let q = RequestQueue::new(2, 100);
        let reg = Registry::new();
        reg.counter("serve/scheduler", "steps", 3);
        let r = handle_request(&get("/healthz"), &q, &reg, &info(), 8, Duration::from_secs(1));
        assert_eq!(r.status, 200);
        assert!(r.body.contains("\"status\":\"ok\""), "{}", r.body);
        let r = handle_request(&get("/metrics"), &q, &reg, &info(), 8, Duration::from_secs(1));
        assert_eq!(r.status, 200);
        assert!(r.body.contains("trace summary"), "{}", r.body);
        assert!(r.body.contains("serve/scheduler"), "{}", r.body);
    }

    #[test]
    fn unknown_route_is_404_and_bad_method_is_405() {
        let q = RequestQueue::new(2, 100);
        let reg = Registry::none();
        let r = handle_request(&get("/nope"), &q, reg, &info(), 8, Duration::from_secs(1));
        assert_eq!(r.status, 404);
        assert!(r.body.contains("\"status\":404"), "{}", r.body);
        let r = handle_request(&get("/v1/generate"), &q, reg, &info(), 8, Duration::from_secs(1));
        assert_eq!(r.status, 405);
        assert!(r.body.contains("\"status\":405"), "{}", r.body);
    }

    #[test]
    fn bad_body_is_400_and_full_queue_is_429() {
        let q = RequestQueue::new(1, 100);
        let reg = Registry::new();
        let post = |body: &str| http::Request {
            method: "POST".into(),
            path: "/v1/generate".into(),
            headers: vec![],
            body: body.as_bytes().to_vec(),
        };
        let r = handle_request(&post("{oops"), &q, &reg, &info(), 8, Duration::from_secs(1));
        assert_eq!(r.status, 400);
        // fill the queue directly, then the handler's submit must 429
        q.submit(GenRequest { prompt: vec![1], max_tokens: 1 }).unwrap();
        let r = handle_request(
            &post(r#"{"prompt": [1]}"#),
            &q,
            &reg,
            &info(),
            8,
            Duration::from_secs(1),
        );
        assert_eq!(r.status, 429);
        assert_eq!(reg.counter_total("serve/http", "queue_full_429"), 1);
    }
}
