//! Continuous-batching decode scheduler: the headline piece of `mase
//! serve` (PR 9).
//!
//! ## Lanes, and why batched == sequential bitwise
//!
//! A [`BatchEngine`] owns one long-lived [`Decoder`] whose group is
//! carved into `lanes` fixed **lanes** of `width` rows each. `width` is
//! the quantizer block height (16) for block formats and 1 for
//! element-wise formats, so lanes are 16-aligned and a `(16, 2)`
//! quantizer block never spans two lanes. Within a lane every row feeds
//! the *same* token: identical rows quantize identically (a block's
//! shared exponent is the max over rows it already contains), layer norm
//! / GELU / embedding are per-row, every packed-GEMM output element
//! accumulates only over `k`, and attention reads only the queried
//! slot's cached rows. A lane is therefore bit-for-bit the group a
//! fresh `width`-row [`Decoder::generate`] call runs on the same prompt
//! — *regardless of what the other lanes are doing*. That independence
//! is the whole determinism contract: given a fixed seed and admission
//! order, continuously-batched tokens equal per-request sequential
//! decodes (asserted by `tests/serve_batching.rs` and mirrored in
//! `scripts/verify_serve_protocol.py`).
//!
//! Prompts are fed one token per tick through the same cached step path
//! (prefill-as-decode): by the PR 7 stacking lemma this is bitwise equal
//! to a stacked prefill, and it lets a request join a *live* group
//! between steps without recomputing anyone else's context.
//!
//! ## Tick state machine
//!
//! ```text
//! step(): compact cache → evict idle lanes → build token row
//!         → Decoder::decode_step → harvest argmax / retire lanes
//! ```
//!
//! A lane is `free` or `live{fed}`; a live lane feeds `prompt[fed]`
//! while `fed < prompt_len`, then its own greedy continuation; after
//! `prompt_len + max_tokens` fed positions it retires (same position
//! count as [`Decoder::generate`], whose final argmax is likewise
//! computed and discarded). Retirement and admission evict the lane's
//! slots ([`Decoder::evict`]); idle lanes feed token 0 and are
//! re-evicted every tick so each costs exactly one score dot per
//! (slot, head, layer). [`Decoder::compact`] runs every tick, so cache
//! memory and the absolute position index stay bounded by the longest
//! live context — the engine can run forever.
//!
//! ## Queue + scheduler loop
//!
//! [`RequestQueue`] is the bounded FIFO between HTTP handler threads and
//! the single scheduler thread ([`run_scheduler`]): `submit` fails fast
//! with 429 at capacity, the loop expires entries older than the
//! admission deadline with 503, admits in FIFO order whenever lanes are
//! free, and steps the engine. All tracing happens on the scheduler
//! thread: one `serve/request` span per completion plus admission /
//! step / retire / eviction counters and per-step [`DecodeStats`]
//! deltas under `serve/engine` — which is what `/metrics` renders.

use crate::formats::{FormatKind, BLOCK_SHAPE};
use crate::frontend::ModelMeta;
use crate::ir::Graph;
use crate::obs::Registry;
use crate::runtime::decode::{DecodeStats, Decoder};
use crate::runtime::interp::{argmax, CpuBackend};
use anyhow::{anyhow, ensure, Result};
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::protocol::{GenRequest, Reply, ServeError};

/// Scheduler knobs (`mase serve` flags map onto these).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Concurrent request lanes (decoder group = `lanes * width`).
    pub lanes: usize,
    /// Bounded FIFO capacity; `submit` beyond this is a 429.
    pub queue_cap: usize,
    /// Queued longer than this without a free lane → 503.
    pub queue_timeout_ms: u64,
    /// Decode budget when a request omits `max_tokens`.
    pub default_max_tokens: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { lanes: 4, queue_cap: 32, queue_timeout_ms: 2000, default_max_tokens: 8 }
    }
}

/// One in-flight request occupying a lane.
#[derive(Debug)]
struct Lane {
    id: u64,
    prompt: Vec<i32>,
    max_tokens: usize,
    /// Tokens fed so far (prompt positions, then generated ones).
    fed: usize,
    generated: Vec<i32>,
    /// Lane-representative logits per fed position (tests only).
    step_logits: Vec<Vec<f32>>,
}

/// A finished request: its generated tokens (and, when
/// [`BatchEngine::keep_logits`] is set, per-position logits for the
/// bitwise parity assertions).
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: u64,
    pub prompt_len: usize,
    pub tokens: Vec<i32>,
    pub step_logits: Vec<Vec<f32>>,
}

/// The continuous-batching core: deterministic, synchronous, directly
/// drivable by tests — the scheduler thread is just a thin loop over
/// [`BatchEngine::admit`] / [`BatchEngine::step`].
pub struct BatchEngine<'a> {
    dec: Decoder<'a>,
    width: usize,
    vocab: usize,
    seq_len: usize,
    lanes: Vec<Option<Lane>>,
    /// Record per-position lane logits into completions (parity tests).
    pub keep_logits: bool,
    /// Slot-steps spent on idle lanes (each costs exactly one score dot
    /// per head and layer — the closed-form dots accounting needs it).
    pub idle_slot_steps: u64,
    /// Slots evicted so far (admission + retirement + idle re-eviction).
    pub evicted_slots: u64,
    ticks: u64,
}

impl<'a> BatchEngine<'a> {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        backend: &CpuBackend,
        graph: &'a Graph,
        meta: &'a ModelMeta,
        weights: &'a [f32],
        fmt_tag: &str,
        qcfg: &'a [f32],
        lanes: usize,
    ) -> Result<BatchEngine<'a>> {
        ensure!(lanes >= 1, "serve needs at least one lane");
        let fmt = FormatKind::from_name(fmt_tag)
            .ok_or_else(|| anyhow!("serve: unknown format tag '{fmt_tag}'"))?;
        // block formats share exponents across 16-row blocks: a request
        // must own whole blocks or co-tenants would perturb its bits
        let width = if fmt.is_block_format() { BLOCK_SHAPE.0 } else { 1 };
        let dec = Decoder::new(backend, graph, meta, weights, fmt_tag, qcfg, lanes * width)?;
        Ok(BatchEngine {
            dec,
            width,
            vocab: meta.vocab,
            seq_len: meta.seq_len,
            lanes: (0..lanes).map(|_| None).collect(),
            keep_logits: false,
            idle_slot_steps: 0,
            evicted_slots: 0,
            ticks: 0,
        })
    }

    /// Decoder rows per lane (16 for block formats, 1 element-wise).
    pub fn width(&self) -> usize {
        self.width
    }

    pub fn free_lanes(&self) -> usize {
        self.lanes.iter().filter(|l| l.is_none()).count()
    }

    pub fn active(&self) -> usize {
        self.lanes.len() - self.free_lanes()
    }

    pub fn is_idle(&self) -> bool {
        self.active() == 0
    }

    pub fn ticks(&self) -> u64 {
        self.ticks
    }

    /// Counted decode work so far (the underlying [`Decoder`]'s stats).
    pub fn stats(&self) -> DecodeStats {
        self.dec.stats
    }

    fn evict_lane(&mut self, lane: usize) -> Result<()> {
        for s in lane * self.width..(lane + 1) * self.width {
            self.dec.evict(s)?;
        }
        self.evicted_slots += self.width as u64;
        Ok(())
    }

    /// Seat a request in a free lane (between steps — never mid-step).
    /// Errors are caller bugs (no free lane) or contract violations the
    /// protocol layer should have rejected.
    pub fn admit(&mut self, id: u64, prompt: Vec<i32>, max_tokens: usize) -> Result<usize> {
        let lane = self
            .lanes
            .iter()
            .position(|l| l.is_none())
            .ok_or_else(|| anyhow!("admit with no free lane"))?;
        ensure!(!prompt.is_empty(), "admit: empty prompt");
        ensure!(max_tokens >= 1, "admit: zero decode budget");
        ensure!(
            prompt.len() + max_tokens <= self.seq_len,
            "admit: prompt {} + {max_tokens} exceeds seq_len {}",
            prompt.len(),
            self.seq_len
        );
        self.evict_lane(lane)?;
        self.lanes[lane] = Some(Lane {
            id,
            prompt,
            max_tokens,
            fed: 0,
            generated: Vec::new(),
            step_logits: Vec::new(),
        });
        Ok(lane)
    }

    /// One scheduler tick: step every live lane one position, harvest
    /// greedy continuations, retire finished requests. No-op when idle.
    pub fn step(&mut self) -> Result<Vec<Completion>> {
        if self.is_idle() {
            return Ok(Vec::new());
        }
        self.dec.compact();
        let group = self.lanes.len() * self.width;
        let mut toks = vec![0i32; group];
        for lane in 0..self.lanes.len() {
            match &self.lanes[lane] {
                Some(l) => {
                    let t = if l.fed < l.prompt.len() {
                        l.prompt[l.fed]
                    } else {
                        l.generated[l.fed - l.prompt.len()]
                    };
                    toks[lane * self.width..(lane + 1) * self.width].fill(t);
                }
                None => {
                    // keep the idle lane's context at one position so its
                    // cost stays O(1) per tick and its rows hold no state
                    self.evict_lane(lane)?;
                    self.idle_slot_steps += self.width as u64;
                }
            }
        }
        let logits = self.dec.decode_step(&toks)?;
        let mut done = Vec::new();
        for lane in 0..self.lanes.len() {
            let Some(l) = self.lanes[lane].as_mut() else { continue };
            let row = lane * self.width;
            let lg = &logits[row * self.vocab..(row + 1) * self.vocab];
            l.fed += 1;
            if self.keep_logits {
                l.step_logits.push(lg.to_vec());
            }
            if l.fed >= l.prompt.len() {
                // the argmax after the last prompt token is the first
                // generated one; the one after the last budgeted token is
                // computed and discarded, exactly like Decoder::generate
                if l.fed - l.prompt.len() < l.max_tokens {
                    l.generated.push(argmax(lg) as i32);
                }
                if l.fed == l.prompt.len() + l.max_tokens {
                    let l = self.lanes[lane].take().unwrap();
                    self.evict_lane(lane)?;
                    done.push(Completion {
                        id: l.id,
                        prompt_len: l.prompt.len(),
                        tokens: l.generated,
                        step_logits: l.step_logits,
                    });
                }
            }
        }
        self.ticks += 1;
        Ok(done)
    }
}

struct Pending {
    id: u64,
    req: GenRequest,
    enqueued: Instant,
    tx: mpsc::Sender<Result<Reply, ServeError>>,
}

struct QueueInner {
    q: VecDeque<Pending>,
    shutdown: bool,
}

/// The bounded FIFO between HTTP handler threads and the scheduler.
pub struct RequestQueue {
    inner: Mutex<QueueInner>,
    cv: Condvar,
    cap: usize,
    timeout: Duration,
    next_id: AtomicU64,
}

impl RequestQueue {
    pub fn new(cap: usize, timeout_ms: u64) -> RequestQueue {
        RequestQueue {
            inner: Mutex::new(QueueInner { q: VecDeque::new(), shutdown: false }),
            cv: Condvar::new(),
            cap: cap.max(1),
            timeout: Duration::from_millis(timeout_ms),
            next_id: AtomicU64::new(0),
        }
    }

    /// Enqueue a validated request. Fails fast with
    /// [`ServeError::QueueFull`] (429) at capacity — in-flight work is
    /// untouched. On success the receiver eventually yields the reply or
    /// a scheduler-side error.
    #[allow(clippy::type_complexity)]
    pub fn submit(
        &self,
        req: GenRequest,
    ) -> Result<mpsc::Receiver<Result<Reply, ServeError>>, ServeError> {
        let mut g = self.inner.lock().unwrap();
        if g.shutdown {
            return Err(ServeError::Internal("server is shutting down".into()));
        }
        if g.q.len() >= self.cap {
            return Err(ServeError::QueueFull { cap: self.cap });
        }
        let (tx, rx) = mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        g.q.push_back(Pending { id, req, enqueued: Instant::now(), tx });
        drop(g);
        self.cv.notify_one();
        Ok(rx)
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Stop admitting; [`run_scheduler`] drains in-flight work and exits.
    pub fn shutdown(&self) {
        self.inner.lock().unwrap().shutdown = true;
        self.cv.notify_all();
    }
}

fn record_stats_delta(reg: &Registry, engine: &BatchEngine, last: &mut (DecodeStats, u64, u64)) {
    if !reg.is_enabled() {
        return;
    }
    let (s, idle, ev) = (engine.stats(), engine.idle_slot_steps, engine.evicted_slots);
    reg.counter("serve/engine", "steps", s.steps - last.0.steps);
    reg.counter(
        "serve/engine",
        "decode_score_dots",
        s.decode_score_dots - last.0.decode_score_dots,
    );
    reg.counter("serve/engine", "idle_slot_steps", idle - last.1);
    reg.counter("serve/engine", "evicted_slots", ev - last.2);
    *last = (s, idle, ev);
}

/// The scheduler loop: admit → step → respond, single-threaded over the
/// engine, until [`RequestQueue::shutdown`] and all lanes drain. All
/// spans/counters are recorded here (one thread, deterministic counted
/// work given a fixed admission order; wall-clock stays summary-only as
/// everywhere in `obs`).
pub fn run_scheduler(engine: &mut BatchEngine, queue: &RequestQueue, reg: &Registry) {
    let mut waiters: BTreeMap<u64, (mpsc::Sender<Result<Reply, ServeError>>, Instant, usize)> =
        BTreeMap::new();
    let mut last = (DecodeStats::default(), 0u64, 0u64);
    loop {
        {
            let mut g = queue.inner.lock().unwrap();
            loop {
                // expire from the front (FIFO ⇒ oldest first)
                while let Some(p) = g.q.front() {
                    if p.enqueued.elapsed() >= queue.timeout {
                        let p = g.q.pop_front().unwrap();
                        let waited = p.enqueued.elapsed().as_millis() as u64;
                        let _ = p.tx.send(Err(ServeError::QueueTimeout { waited_ms: waited }));
                        reg.counter("serve/scheduler", "queue_timeout_503", 1);
                    } else {
                        break;
                    }
                }
                if engine.free_lanes() == 0 || g.q.is_empty() {
                    break;
                }
                let p = g.q.pop_front().unwrap();
                let prompt_len = p.req.prompt.len();
                match engine.admit(p.id, p.req.prompt, p.req.max_tokens) {
                    Ok(_) => {
                        waiters.insert(p.id, (p.tx, p.enqueued, prompt_len));
                        reg.counter("serve/scheduler", "admitted", 1);
                    }
                    Err(e) => {
                        let _ = p.tx.send(Err(ServeError::Internal(e.to_string())));
                    }
                }
            }
            if engine.is_idle() {
                if g.shutdown {
                    break;
                }
                if g.q.is_empty() {
                    // nothing to do: sleep until a submit (bounded so a
                    // racing shutdown or a queued-then-expired entry is
                    // still noticed promptly)
                    let _ = queue.cv.wait_timeout(g, Duration::from_millis(50)).unwrap();
                    continue;
                }
            }
        }
        match engine.step() {
            Ok(done) => {
                reg.counter("serve/scheduler", "steps", 1);
                record_stats_delta(reg, engine, &mut last);
                for c in done {
                    reg.counter("serve/scheduler", "retired", 1);
                    if let Some((tx, enqueued, prompt_len)) = waiters.remove(&c.id) {
                        let latency_ms = enqueued.elapsed().as_millis() as u64;
                        {
                            let _span = reg
                                .span("serve/request")
                                .tag("id", c.id.to_string())
                                .tag("prompt_len", prompt_len.to_string())
                                .tag("tokens", c.tokens.len().to_string());
                        }
                        let _ = tx.send(Ok(Reply {
                            id: c.id,
                            prompt_len,
                            tokens: c.tokens,
                            latency_ms,
                        }));
                    }
                }
            }
            Err(e) => {
                // the engine is a deterministic state machine over
                // validated inputs; failing here means a bug — fail every
                // waiter loudly rather than serving silent garbage
                let msg = format!("decode engine failed: {e:#}");
                for (_, (tx, _, _)) in std::mem::take(&mut waiters) {
                    let _ = tx.send(Err(ServeError::Internal(msg.clone())));
                }
                let mut g = queue.inner.lock().unwrap();
                g.shutdown = true;
                for p in g.q.drain(..) {
                    let _ = p.tx.send(Err(ServeError::Internal(msg.clone())));
                }
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn queue_fails_fast_at_capacity() {
        let q = RequestQueue::new(2, 1000);
        let r1 = q.submit(GenRequest { prompt: vec![1], max_tokens: 1 });
        let r2 = q.submit(GenRequest { prompt: vec![2], max_tokens: 1 });
        assert!(r1.is_ok() && r2.is_ok());
        match q.submit(GenRequest { prompt: vec![3], max_tokens: 1 }) {
            Err(ServeError::QueueFull { cap }) => assert_eq!(cap, 2),
            other => panic!("expected 429, got {other:?}"),
        }
        assert_eq!(q.len(), 2, "a rejected submit leaves the queue untouched");
    }

    #[test]
    fn queue_rejects_after_shutdown() {
        let q = RequestQueue::new(4, 1000);
        q.shutdown();
        assert!(matches!(
            q.submit(GenRequest { prompt: vec![1], max_tokens: 1 }),
            Err(ServeError::Internal(_))
        ));
    }

    #[test]
    fn queue_ids_are_fifo() {
        let q = RequestQueue::new(4, 1000);
        for t in 0..3 {
            q.submit(GenRequest { prompt: vec![t], max_tokens: 1 }).unwrap();
        }
        let g = q.inner.lock().unwrap();
        let ids: Vec<u64> = g.q.iter().map(|p| p.id).collect();
        assert_eq!(ids, vec![0, 1, 2]);
    }
}
