//! Wire protocol for `mase serve`: JSON request parsing/validation and
//! response rendering through [`crate::util::json`] (no `serde` in this
//! offline environment — the depth-limited parser there is the one
//! security-relevant piece, since this module decodes network input).
//!
//! `POST /v1/generate` body:
//!
//! ```json
//! {"prompt": [12, 407, 3], "max_tokens": 8}
//! ```
//!
//! or, for clients that don't want to pick token ids by hand, a
//! deterministic prompt sampled from the Markov eval corpus:
//!
//! ```json
//! {"prompt_len": 4, "stream": 11, "max_tokens": 8}
//! ```
//!
//! Success response (`200`):
//!
//! ```json
//! {"id":3,"model":"toy-lm","fmt":"mxint","prompt_len":4,
//!  "tokens":[17,211,5,90],"latency_ms":12}
//! ```
//!
//! Errors render as `{"error": "...", "status": N}` with the matching
//! HTTP status: `400` malformed/invalid body, `429` bounded queue full,
//! `503` queued past the admission deadline, `500` internal.

use crate::data::MarkovCorpus;
use crate::util::json::Json;
use std::collections::BTreeMap;

/// Static facts about the served model, threaded into parsing (bounds
/// checks) and rendering (response metadata).
#[derive(Debug, Clone)]
pub struct ServeInfo {
    pub model: String,
    pub fmt: String,
    pub bits: f32,
    pub vocab: usize,
    pub seq_len: usize,
    pub lanes: usize,
    /// Decoder rows per request lane (16 for block formats, 1 else).
    pub width: usize,
}

/// A validated generation request: token-id prompt + decode budget.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GenRequest {
    pub prompt: Vec<i32>,
    pub max_tokens: usize,
}

/// Scheduler-side completion handed back to the HTTP layer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Reply {
    pub id: u64,
    pub prompt_len: usize,
    pub tokens: Vec<i32>,
    /// Milliseconds from enqueue to completion.
    pub latency_ms: u64,
}

/// Service-level failures, each with a fixed HTTP status.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// Malformed JSON or a request violating the model bounds (400).
    BadRequest(String),
    /// The bounded FIFO request queue is at capacity (429).
    QueueFull { cap: usize },
    /// Queued longer than the admission deadline (503).
    QueueTimeout { waited_ms: u64 },
    /// Scheduler/engine failure (500).
    Internal(String),
}

impl ServeError {
    pub fn status(&self) -> u16 {
        match self {
            ServeError::BadRequest(_) => 400,
            ServeError::QueueFull { .. } => 429,
            ServeError::QueueTimeout { .. } => 503,
            ServeError::Internal(_) => 500,
        }
    }

    pub fn message(&self) -> String {
        match self {
            ServeError::BadRequest(m) => m.clone(),
            ServeError::QueueFull { cap } => {
                format!("request queue full ({cap} waiting); retry later")
            }
            ServeError::QueueTimeout { waited_ms } => {
                format!("queued {waited_ms} ms without a free decode lane; retry later")
            }
            ServeError::Internal(m) => m.clone(),
        }
    }
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} {}", self.status(), self.message())
    }
}

impl std::error::Error for ServeError {}

fn bad(msg: impl Into<String>) -> ServeError {
    ServeError::BadRequest(msg.into())
}

/// Parse + validate a `/v1/generate` body against the served model.
pub fn parse_generate(
    body: &str,
    info: &ServeInfo,
    default_max_tokens: usize,
) -> Result<GenRequest, ServeError> {
    let j = Json::parse(body).map_err(|e| bad(e.to_string()))?;
    let obj = j.as_obj().ok_or_else(|| bad("request body must be a JSON object"))?;
    for key in obj.keys() {
        if !matches!(key.as_str(), "prompt" | "prompt_len" | "stream" | "max_tokens") {
            return Err(bad(format!(
                "unknown field '{key}' (expected prompt | prompt_len | stream | max_tokens)"
            )));
        }
    }
    let max_tokens = match obj.get("max_tokens") {
        None => default_max_tokens,
        Some(v) => {
            let n = v.as_f64().ok_or_else(|| bad("max_tokens must be a number"))?;
            if n < 1.0 || n.fract() != 0.0 {
                return Err(bad("max_tokens must be a positive integer"));
            }
            n as usize
        }
    };
    let prompt: Vec<i32> = match (obj.get("prompt"), obj.get("prompt_len")) {
        (Some(_), Some(_)) => return Err(bad("give either prompt or prompt_len, not both")),
        (Some(p), None) => {
            let arr = p.as_arr().ok_or_else(|| bad("prompt must be an array of token ids"))?;
            let mut toks = Vec::with_capacity(arr.len());
            for (i, t) in arr.iter().enumerate() {
                let n = t.as_f64().ok_or_else(|| bad(format!("prompt[{i}] is not a number")))?;
                if n.fract() != 0.0 || n < 0.0 || n >= info.vocab as f64 {
                    return Err(bad(format!(
                        "prompt[{i}] = {n} outside token range 0..{}",
                        info.vocab
                    )));
                }
                toks.push(n as i32);
            }
            toks
        }
        (None, Some(l)) => {
            let len = l.as_f64().ok_or_else(|| bad("prompt_len must be a number"))? as usize;
            if len < 1 || len > info.seq_len {
                return Err(bad(format!("prompt_len outside 1..={}", info.seq_len)));
            }
            let stream = obj
                .get("stream")
                .map(|s| s.as_f64().ok_or_else(|| bad("stream must be a number")))
                .transpose()?
                .unwrap_or(0.0) as u64;
            // deterministic prompt from the shared eval corpus: the same
            // (stream, prompt_len) always yields the same tokens
            MarkovCorpus::new(7).batch(stream, 1, len)
        }
        (None, None) => return Err(bad("missing prompt (or prompt_len + stream)")),
    };
    if prompt.is_empty() {
        return Err(bad("prompt must hold at least one token"));
    }
    if prompt.len() + max_tokens > info.seq_len {
        return Err(bad(format!(
            "prompt {} + max_tokens {max_tokens} exceeds model seq_len {}",
            prompt.len(),
            info.seq_len
        )));
    }
    Ok(GenRequest { prompt, max_tokens })
}

/// Render a completed generation as the `200` response body.
pub fn render_reply(info: &ServeInfo, r: &Reply) -> String {
    let mut m = BTreeMap::new();
    m.insert("id".to_string(), Json::Num(r.id as f64));
    m.insert("model".to_string(), Json::Str(info.model.clone()));
    m.insert("fmt".to_string(), Json::Str(info.fmt.clone()));
    m.insert("prompt_len".to_string(), Json::Num(r.prompt_len as f64));
    m.insert(
        "tokens".to_string(),
        Json::Arr(r.tokens.iter().map(|&t| Json::Num(t as f64)).collect()),
    );
    m.insert("latency_ms".to_string(), Json::Num(r.latency_ms as f64));
    format!("{}\n", Json::Obj(m))
}

/// Render a [`ServeError`] as its JSON error body.
pub fn render_error(e: &ServeError) -> String {
    render_status_error(e.status(), &e.message())
}

/// Error body for statuses with no [`ServeError`] variant (404, 405,
/// and the HTTP-layer 4xx/5xx refusals).
pub fn render_status_error(status: u16, msg: &str) -> String {
    let mut m = BTreeMap::new();
    m.insert("error".to_string(), Json::Str(msg.to_string()));
    m.insert("status".to_string(), Json::Num(status as f64));
    format!("{}\n", Json::Obj(m))
}

/// The `/healthz` body: static service facts, no engine state.
pub fn render_health(info: &ServeInfo) -> String {
    let mut m = BTreeMap::new();
    m.insert("status".to_string(), Json::Str("ok".to_string()));
    m.insert("model".to_string(), Json::Str(info.model.clone()));
    m.insert("fmt".to_string(), Json::Str(info.fmt.clone()));
    m.insert("bits".to_string(), Json::Num(info.bits as f64));
    m.insert("seq_len".to_string(), Json::Num(info.seq_len as f64));
    m.insert("lanes".to_string(), Json::Num(info.lanes as f64));
    m.insert("width".to_string(), Json::Num(info.width as f64));
    format!("{}\n", Json::Obj(m))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn info() -> ServeInfo {
        ServeInfo {
            model: "toy-lm".into(),
            fmt: "mxint".into(),
            bits: 7.0,
            vocab: 512,
            seq_len: 32,
            lanes: 4,
            width: 16,
        }
    }

    #[test]
    fn parses_explicit_prompt() {
        let r = parse_generate(r#"{"prompt": [1, 2, 511], "max_tokens": 3}"#, &info(), 8).unwrap();
        assert_eq!(r, GenRequest { prompt: vec![1, 2, 511], max_tokens: 3 });
    }

    #[test]
    fn default_max_tokens_applies() {
        let r = parse_generate(r#"{"prompt": [5]}"#, &info(), 6).unwrap();
        assert_eq!(r.max_tokens, 6);
    }

    #[test]
    fn corpus_prompt_is_deterministic() {
        let a = parse_generate(r#"{"prompt_len": 4, "stream": 11}"#, &info(), 8).unwrap();
        let b = parse_generate(r#"{"prompt_len": 4, "stream": 11}"#, &info(), 8).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.prompt.len(), 4);
        assert!(a.prompt.iter().all(|&t| (0..512).contains(&t)));
        let c = parse_generate(r#"{"prompt_len": 4, "stream": 12}"#, &info(), 8).unwrap();
        assert_ne!(a.prompt, c.prompt, "different streams give different prompts");
    }

    #[test]
    fn rejects_out_of_contract_bodies() {
        let i = info();
        for (body, why) in [
            ("[1,2]", "not an object"),
            ("{\"prompt\": [1,2,", "truncated json"),
            (r#"{"prompt": []}"#, "empty prompt"),
            (r#"{"prompt": [512]}"#, "token out of vocab"),
            (r#"{"prompt": [-1]}"#, "negative token"),
            (r#"{"prompt": [1.5]}"#, "fractional token"),
            (r#"{"prompt": [1], "max_tokens": 0}"#, "zero budget"),
            (r#"{"prompt": [1], "max_tokens": 32}"#, "exceeds seq_len"),
            (r#"{"prompt": [1], "prompt_len": 2}"#, "both prompt forms"),
            (r#"{"prompt": [1], "tokens": 2}"#, "unknown field"),
            (r#"{}"#, "no prompt at all"),
        ] {
            let e = parse_generate(body, &i, 8).unwrap_err();
            assert_eq!(e.status(), 400, "{why}: {e}");
        }
    }

    #[test]
    fn reply_renders_compact_json() {
        let body = render_reply(
            &info(),
            &Reply { id: 3, prompt_len: 2, tokens: vec![7, 8], latency_ms: 12 },
        );
        let j = Json::parse(body.trim()).unwrap();
        assert_eq!(j.get("id").unwrap().as_usize(), Some(3));
        assert_eq!(j.at(&["tokens", "1"]).unwrap().as_f64(), Some(8.0));
        assert_eq!(j.get("model").unwrap().as_str(), Some("toy-lm"));
    }

    #[test]
    fn errors_carry_their_status() {
        assert_eq!(ServeError::QueueFull { cap: 4 }.status(), 429);
        assert_eq!(ServeError::QueueTimeout { waited_ms: 9 }.status(), 503);
        let body = render_error(&ServeError::QueueFull { cap: 4 });
        let j = Json::parse(body.trim()).unwrap();
        assert_eq!(j.get("status").unwrap().as_usize(), Some(429));
    }
}
