//! Minimal HTTP/1.1 on `std::net` — the vendored-crates-offline
//! constraint rules out tokio/axum/hyper, and the service needs exactly
//! three routes with fixed-length JSON bodies, so a hand-rolled
//! request reader and response writer are sufficient and fully tested.
//!
//! Scope (deliberate):
//! - one request per connection (`Connection: close` on every response);
//! - fixed `Content-Length` bodies only (no chunked requests);
//! - header block capped at [`MAX_HEADER_BYTES`], body at
//!   [`MAX_BODY_BYTES`] — malformed or oversized input maps to a 4xx
//!   [`HttpError`] the caller renders, I/O failures just drop the
//!   connection.
//!
//! Parsing is generic over [`BufRead`] so the unit tests drive it from
//! in-memory cursors; the server wraps each [`std::net::TcpStream`] in a
//! `BufReader` with read/write timeouts set by the accept loop.

use std::io::{self, BufRead, Read, Write};

/// Cap on the request line + header block.
pub const MAX_HEADER_BYTES: usize = 16 * 1024;
/// Cap on a request body (`Content-Length` above this is refused).
pub const MAX_BODY_BYTES: usize = 1 << 20;

/// A parsed request: method + path + headers + raw body bytes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Request {
    pub method: String,
    pub path: String,
    /// Header `(name, value)` pairs in arrival order, names lowercased.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    /// First value of `name` (ASCII case-insensitive lookup).
    pub fn header(&self, name: &str) -> Option<&str> {
        let want = name.to_ascii_lowercase();
        self.headers.iter().find(|(n, _)| *n == want).map(|(_, v)| v.as_str())
    }
}

/// A request the reader refused, with the status the caller should send.
#[derive(Debug)]
pub enum HttpError {
    /// Protocol violation → respond with this status + message.
    Bad { status: u16, msg: String },
    /// Transport failure → drop the connection silently.
    Io(io::Error),
}

impl From<io::Error> for HttpError {
    fn from(e: io::Error) -> Self {
        HttpError::Io(e)
    }
}

fn bad(status: u16, msg: impl Into<String>) -> HttpError {
    HttpError::Bad { status, msg: msg.into() }
}

/// Read one request. Returns `Ok(None)` on a clean EOF before any bytes
/// (client connected and went away).
pub fn read_request<R: BufRead>(r: &mut R) -> Result<Option<Request>, HttpError> {
    let mut head = 0usize;
    let mut line = String::new();
    if r.read_line(&mut line)? == 0 {
        return Ok(None);
    }
    head += line.len();
    let req_line = line.trim_end_matches(['\r', '\n']);
    let mut parts = req_line.split_whitespace();
    let (method, path, version) = match (parts.next(), parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(p), Some(v), None) => (m.to_string(), p.to_string(), v),
        _ => return Err(bad(400, format!("malformed request line '{req_line}'"))),
    };
    if !version.starts_with("HTTP/1.") {
        return Err(bad(505, format!("unsupported protocol '{version}'")));
    }
    let mut headers = Vec::new();
    loop {
        let mut hl = String::new();
        if r.read_line(&mut hl)? == 0 {
            return Err(bad(400, "connection closed inside header block"));
        }
        head += hl.len();
        if head > MAX_HEADER_BYTES {
            return Err(bad(431, format!("header block exceeds {MAX_HEADER_BYTES} bytes")));
        }
        let hl = hl.trim_end_matches(['\r', '\n']);
        if hl.is_empty() {
            break;
        }
        let (name, value) = hl
            .split_once(':')
            .ok_or_else(|| bad(400, format!("malformed header '{hl}'")))?;
        headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }
    let req = Request { method, path, headers, body: Vec::new() };
    let body_len = match req.header("content-length") {
        None => 0,
        Some(v) => v
            .parse::<usize>()
            .map_err(|_| bad(400, format!("bad content-length '{v}'")))?,
    };
    if body_len > MAX_BODY_BYTES {
        return Err(bad(413, format!("body of {body_len} bytes exceeds {MAX_BODY_BYTES}")));
    }
    let mut body = vec![0u8; body_len];
    r.read_exact(&mut body)?;
    Ok(Some(Request { body, ..req }))
}

/// A response to serialize: status + content type + body.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: String,
}

impl Response {
    pub fn json(status: u16, body: String) -> Response {
        Response { status, content_type: "application/json", body }
    }

    pub fn text(status: u16, body: String) -> Response {
        Response { status, content_type: "text/plain; charset=utf-8", body }
    }
}

pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        505 => "HTTP Version Not Supported",
        _ => "Unknown",
    }
}

/// Serialize `resp` (always `Connection: close` — one request per
/// connection keeps the pool accounting trivial).
pub fn write_response<W: Write>(w: &mut W, resp: &Response) -> io::Result<()> {
    write!(
        w,
        "HTTP/1.1 {} {}\r\ncontent-type: {}\r\ncontent-length: {}\r\nconnection: close\r\n\r\n{}",
        resp.status,
        reason(resp.status),
        resp.content_type,
        resp.body.len(),
        resp.body
    )?;
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn parse(raw: &str) -> Result<Option<Request>, HttpError> {
        read_request(&mut Cursor::new(raw.as_bytes().to_vec()))
    }

    #[test]
    fn parses_post_with_body() {
        let req = parse(
            "POST /v1/generate HTTP/1.1\r\nHost: x\r\nContent-Length: 13\r\n\r\n{\"prompt\":[]}",
        )
        .unwrap()
        .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/generate");
        assert_eq!(req.header("host"), Some("x"));
        assert_eq!(req.header("HOST"), Some("x"), "lookup is case-insensitive");
        assert_eq!(req.body, b"{\"prompt\":[]}");
    }

    #[test]
    fn parses_get_without_body() {
        let req = parse("GET /healthz HTTP/1.0\r\n\r\n").unwrap().unwrap();
        assert_eq!(req.method, "GET");
        assert!(req.body.is_empty());
    }

    #[test]
    fn clean_eof_is_none() {
        assert!(parse("").unwrap().is_none());
    }

    #[test]
    fn malformed_inputs_get_4xx() {
        for (raw, want) in [
            ("GARBAGE\r\n\r\n", 400),
            ("GET /x HTTP/1.1\r\nno-colon-here\r\n\r\n", 400),
            ("GET /x HTTP/1.1\r\nContent-Length: nope\r\n\r\n", 400),
            ("GET /x HTTP/2.0\r\n\r\n", 505),
            (
                &format!("GET /x HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY_BYTES + 1),
                413,
            ),
        ] {
            match parse(raw) {
                Err(HttpError::Bad { status, .. }) => assert_eq!(status, want, "{raw:?}"),
                other => panic!("{raw:?} should be refused, got {other:?}"),
            }
        }
    }

    #[test]
    fn oversized_header_block_is_431() {
        let raw = format!("GET /x HTTP/1.1\r\nbig: {}\r\n\r\n", "a".repeat(MAX_HEADER_BYTES));
        match parse(&raw) {
            Err(HttpError::Bad { status, .. }) => assert_eq!(status, 431),
            other => panic!("expected 431, got {other:?}"),
        }
    }

    #[test]
    fn truncated_body_is_io_error() {
        let raw = "POST /x HTTP/1.1\r\nContent-Length: 10\r\n\r\nshort";
        assert!(matches!(parse(raw), Err(HttpError::Io(_))));
    }

    #[test]
    fn response_wire_format() {
        let mut out = Vec::new();
        write_response(&mut out, &Response::json(200, "{\"ok\":true}".into())).unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 200 OK\r\n"), "{s}");
        assert!(s.contains("content-length: 11\r\n"), "{s}");
        assert!(s.contains("connection: close\r\n"), "{s}");
        assert!(s.ends_with("\r\n\r\n{\"ok\":true}"), "{s}");
    }
}
