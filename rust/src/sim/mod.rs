//! Cycle-approximate simulator for handshake dataflow pipelines — the
//! stand-in for the paper's on-board Alveo U250 throughput measurements.
//!
//! ## Model: tiles, beats, channels
//!
//! Each IR op becomes a node consuming/producing *tiles* over
//! latency-insensitive (ready/valid) channels with finite FIFO depth.
//! A node fires when all inputs have a tile and all outputs have space.
//!
//! Since PR 5 the channels are *bandwidth-aware*: every dataflow edge
//! carries bit-packed MX words, so a tile's payload is its **measured**
//! packed size ([`crate::packed::packed_bits_for`] — shared exponents,
//! guard bits and word-alignment padding included), and every channel
//! has a finite bit-width ([`SimConfig::channel_bits`], plumbed from the
//! device model's [`crate::hw::Device::channel_bits`]). One firing
//! streams its tile in `beats = ceil(tile_bits / channel_bits)` cycles
//! and occupies `max(compute II, beats)`: an under-provisioned channel
//! serializes transfers and stalls the pipeline exactly like a real
//! AXI-stream fabric, and a wider number format is *measurably slower*
//! through the same fabric. `channel_bits = 0` (unbounded) degrades
//! bit-identically to the pre-PR-5 tile model.
//!
//! Stall cycles are attributed to their cause: a consumer starved behind
//! a transfer-bound channel credits the **channel**
//! ([`EdgeReport::transfer_stalled`]), not the consumer node, so the
//! Fig. 1 per-node stall table shows only genuine compute/backpressure
//! stalls.
//!
//! This reproduces the schedules of Fig. 1e/1f: a sequential
//! (non-dataflow) run executes one op at a time; the pipelined dataflow
//! run overlaps inferences, and under-buffered edges stall exactly as in
//! real handshake fabrics.
//!
//! Used to (a) regenerate Fig. 1e/1f, and (b) cross-validate the
//! closed-form throughput regression in [`crate::hw::throughput`]
//! (EXPERIMENTS.md ablation), whose streamed per-op cycle count
//! ([`crate::hw::throughput::op_cycles_streamed`]) applies the same
//! `max(compute, tiles x beats)` rule in closed form.
//!
//! Structure: [`engine`] owns the generic event loop
//! ([`simulate`] over [`NodeSpec`]s with a [`SimConfig`], producing a
//! [`SimReport`] of cycles, utilization, per-node stalls and per-edge
//! channel counters). This module adds the IR glue: lowering a
//! quantized+parallelized [`crate::ir::Graph`] into node specs
//! (latencies from [`crate::hw::throughput`], tile payloads from
//! [`crate::packed`], FIFO depths from the §4.2 buffer insertion) and
//! the [`simulated_throughput`] / [`simulated_throughput_at`]
//! conveniences the integration tests and Fig. 1 bench call.

pub mod engine;

pub use engine::{
    simulate, simulate_traced, EdgeReport, EdgeStall, Firing, NodeSpec, SimConfig, SimReport,
    SimTrace,
};

use crate::hw::throughput::{op_cycles, op_tile_bits, op_tiles_per_inference};
use crate::ir::{Graph, OpKind};

/// Ancestor sets per op (transitive closure over dataflow edges) — used
/// to detect reconvergent (skip/residual) edges that need buffer
/// insertion (§4.2).
fn ancestor_sets(g: &Graph) -> Vec<std::collections::HashSet<usize>> {
    let mut anc: Vec<std::collections::HashSet<usize>> = vec![Default::default(); g.ops.len()];
    for &op_id in &g.topo_order() {
        let op = g.op(op_id);
        let mut set = std::collections::HashSet::new();
        for &a in &op.args {
            if let Some(p) = g.value(a).producer {
                set.insert(p.0);
                set.extend(anc[p.0].iter().copied());
            }
        }
        anc[op_id.0] = set;
    }
    anc
}

/// Build simulator nodes from an IR graph: one node per op, channel per
/// dataflow edge, II from the throughput model's per-tile cycle count,
/// tile payload from the measured packed layout of the op's result
/// tensor (format + precision over the tile shape — what actually
/// crosses the channel, exponent bytes and padding included).
/// Reconvergent edges (a producer that is also an ancestor of one of the
/// consumer's other producers — residual adds, attention's K branch) get
/// one inference of buffer credit: the paper's §4.2 buffer insertion,
/// without which the handshake pipeline deadlocks.
pub fn nodes_from_graph(g: &Graph) -> Vec<NodeSpec> {
    let anc = ancestor_sets(g);
    let mut nodes = Vec::with_capacity(g.ops.len());
    for op in &g.ops {
        let tile = op.results.first().map(|&r| g.value(r).attrs.tile).unwrap_or((1, 1));
        let total = op_cycles(g, op, tile);
        // Zero-work interface ops (input/output) are not compute stages:
        // one token per inference, one cycle, free transfer.
        let (tiles, ii, tile_bits) = if total == 0.0 {
            (1u64, 1u64, 0u64)
        } else {
            let tiles = op_tiles_per_inference(g, op, tile);
            let ii = (total / tiles as f64).ceil().max(1.0) as u64;
            (tiles, ii, op_tile_bits(g, op, tile))
        };
        let preds: Vec<usize> = op
            .args
            .iter()
            .filter_map(|&a| g.value(a).producer.map(|p| p.0))
            .collect();
        // buffer insertion on reconvergent edges: pred p gets a deep
        // buffer if it is an ancestor of another pred of this op
        let pred_buffer: Vec<f64> = preds
            .iter()
            .map(|&p| {
                let reconv = preds.iter().any(|&q| q != p && anc[q].contains(&p));
                if reconv {
                    1.0
                } else {
                    0.0
                }
            })
            .collect();
        nodes.push(NodeSpec {
            name: format!("{}:{}", op.id.0, op.kind.name()),
            preds,
            pred_buffer,
            ii,
            tiles_per_inference: tiles,
            is_source: op.kind == OpKind::Input,
            out_tile_bits: tile_bits,
        });
    }
    nodes
}

/// Simulated steady-state throughput (inferences/s) of the dataflow
/// schedule for `inferences` back-to-back inferences, with **unbounded**
/// channels — the legacy tile model, bit-identical to the pre-beat-model
/// simulator. Use [`simulated_throughput_at`] to model finite channel
/// widths.
pub fn simulated_throughput(g: &Graph, clock_hz: f64, inferences: u64) -> f64 {
    simulated_throughput_at(g, clock_hz, inferences, SimConfig::UNBOUNDED)
}

/// Simulated steady-state throughput (inferences/s) with every dataflow
/// channel `channel_bits` wide: packed tiles stream in
/// `ceil(tile_bits / channel_bits)` beats (0 = unbounded).
pub fn simulated_throughput_at(
    g: &Graph,
    clock_hz: f64,
    inferences: u64,
    channel_bits: u64,
) -> f64 {
    let nodes = nodes_from_graph(g);
    let report = simulate(
        &nodes,
        &SimConfig { inferences, fifo_depth: 4, sequential: false, channel_bits },
    );
    if report.cycles == 0 {
        return 0.0;
    }
    inferences as f64 / (report.cycles as f64 / clock_hz)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::{FormatKind, Precision};
    use crate::ir::{Graph, TensorType};

    fn chain_graph() -> Graph {
        let mut g = Graph::new("chain");
        let x = g.add_input("x", TensorType::fp32(vec![32, 64]));
        let w = g.new_value(
            "w",
            TensorType { shape: vec![64, 64], format: FormatKind::MxInt, precision: Precision::new(5.0, 0.0) },
            None,
        );
        let h = g.add_op(OpKind::Linear, vec![x], vec![w], "h", TensorType::fp32(vec![32, 64]), None);
        let y = g.add_op(OpKind::Gelu, vec![h], vec![], "y", TensorType::fp32(vec![32, 64]), None);
        g.value_mut(h).attrs.tile = (16, 16);
        g.value_mut(y).attrs.tile = (16, 16);
        g.outputs.push(y);
        g
    }

    #[test]
    fn nodes_mirror_ops() {
        let g = chain_graph();
        let nodes = nodes_from_graph(&g);
        assert_eq!(nodes.len(), g.ops.len());
        assert!(nodes[0].is_source);
        assert_eq!(nodes[2].preds, vec![1]);
    }

    #[test]
    fn tile_payloads_are_measured_packed_bits() {
        let g = chain_graph();
        let nodes = nodes_from_graph(&g);
        // input: interface token, free transfer
        assert_eq!(nodes[0].out_tile_bits, 0);
        // linear/gelu results are fp32[16,16] tiles: 256 * 32 bits
        let expect = crate::packed::packed_bits_for(
            FormatKind::Fp32,
            Precision::new(32.0, 0.0),
            &[16, 16],
        );
        assert_eq!(nodes[1].out_tile_bits, expect);
        assert_eq!(nodes[2].out_tile_bits, expect);
        assert_eq!(expect, 16 * 16 * 32);
    }

    #[test]
    fn dataflow_beats_sequential() {
        // The Fig. 1e vs 1f claim: pipelining raises throughput.
        let g = chain_graph();
        let nodes = nodes_from_graph(&g);
        let cfg = |sequential| SimConfig {
            inferences: 8,
            fifo_depth: 4,
            sequential,
            channel_bits: SimConfig::UNBOUNDED,
        };
        let df = simulate(&nodes, &cfg(false));
        let seq = simulate(&nodes, &cfg(true));
        assert!(df.cycles < seq.cycles, "dataflow {} vs sequential {}", df.cycles, seq.cycles);
    }

    #[test]
    fn narrow_channels_lower_simulated_throughput() {
        let g = chain_graph();
        let clock = 250e6;
        let unbounded = simulated_throughput(&g, clock, 8);
        let narrow = simulated_throughput_at(&g, clock, 8, 32);
        assert!(
            narrow < unbounded,
            "32-bit channels must slow a 8192-bit/tile stream: {narrow} vs {unbounded}"
        );
    }

    #[test]
    fn simulator_close_to_regression_model() {
        // Cross-validation: simulated throughput within 2x of the closed
        // form (they differ by fill/drain and stall effects). Both sides
        // model the device's channel width.
        let g = chain_graph();
        let d = crate::hw::Device::u250();
        let reg = crate::hw::throughput::pipeline_throughput(&g, &d);
        let sim = simulated_throughput_at(&g, d.clock_hz, 16, d.channel_bits);
        let ratio = sim / reg;
        assert!(ratio > 0.4 && ratio < 2.5, "sim {sim} reg {reg}");
    }
}
