//! Cycle-approximate simulator for handshake dataflow pipelines — the
//! stand-in for the paper's on-board Alveo U250 throughput measurements.
//!
//! Model: each IR op becomes a node consuming/producing *tiles* over
//! latency-insensitive (ready/valid) channels with finite FIFO depth.
//! A node fires when all inputs have a tile and all outputs have space,
//! then occupies `ii` cycles. This reproduces the schedules of Fig. 1e/1f:
//! a sequential (non-dataflow) run executes one op at a time; the
//! pipelined dataflow run overlaps inferences, and under-buffered edges
//! stall exactly as in real handshake fabrics.
//!
//! Used to (a) regenerate Fig. 1e/1f, and (b) cross-validate the
//! closed-form throughput regression in [`crate::hw::throughput`]
//! (EXPERIMENTS.md ablation).
//!
//! Structure: [`engine`] owns the generic event loop
//! ([`simulate`] over [`NodeSpec`]s with a [`SimConfig`], producing a
//! [`SimReport`] of cycles, utilization and per-node stalls, where
//! ready-but-blocked nodes are credited the full width of each clock
//! jump). This module adds the IR glue: lowering a quantized+parallelized
//! [`crate::ir::Graph`] into node specs (latencies from
//! [`crate::hw::throughput`], FIFO depths from the §4.2 buffer
//! insertion) and the [`simulated_throughput`] convenience the
//! integration tests and Fig. 1 bench call.

pub mod engine;

pub use engine::{simulate, NodeSpec, SimConfig, SimReport};

use crate::hw::throughput::op_cycles;
use crate::ir::{Graph, OpKind};

/// Ancestor sets per op (transitive closure over dataflow edges) — used
/// to detect reconvergent (skip/residual) edges that need buffer
/// insertion (§4.2).
fn ancestor_sets(g: &Graph) -> Vec<std::collections::HashSet<usize>> {
    let mut anc: Vec<std::collections::HashSet<usize>> = vec![Default::default(); g.ops.len()];
    for &op_id in &g.topo_order() {
        let op = g.op(op_id);
        let mut set = std::collections::HashSet::new();
        for &a in &op.args {
            if let Some(p) = g.value(a).producer {
                set.insert(p.0);
                set.extend(anc[p.0].iter().copied());
            }
        }
        anc[op_id.0] = set;
    }
    anc
}

/// Build simulator nodes from an IR graph: one node per op, channel per
/// dataflow edge, II from the throughput model's per-tile cycle count.
/// Reconvergent edges (a producer that is also an ancestor of one of the
/// consumer's other producers — residual adds, attention's K branch) get
/// one inference of buffer credit: the paper's §4.2 buffer insertion,
/// without which the handshake pipeline deadlocks.
pub fn nodes_from_graph(g: &Graph) -> Vec<NodeSpec> {
    let anc = ancestor_sets(g);
    let mut nodes = Vec::with_capacity(g.ops.len());
    for op in &g.ops {
        let tile = op.results.first().map(|&r| g.value(r).attrs.tile).unwrap_or((1, 1));
        let total = op_cycles(g, op, tile);
        // Zero-work interface ops (input/output) are not compute stages:
        // one token per inference, one cycle.
        let (tiles, ii) = if total == 0.0 {
            (1u64, 1u64)
        } else {
            // tiles per inference = output elements / tile size
            let out_elems: usize = op.results.iter().map(|&r| g.value(r).ty.elements()).sum();
            let tile_elems = (tile.0 * tile.1).max(1);
            let tiles = ((out_elems.max(1) + tile_elems - 1) / tile_elems) as u64;
            let ii = (total / tiles as f64).ceil().max(1.0) as u64;
            (tiles, ii)
        };
        let preds: Vec<usize> = op
            .args
            .iter()
            .filter_map(|&a| g.value(a).producer.map(|p| p.0))
            .collect();
        // buffer insertion on reconvergent edges: pred p gets a deep
        // buffer if it is an ancestor of another pred of this op
        let pred_buffer: Vec<f64> = preds
            .iter()
            .map(|&p| {
                let reconv = preds.iter().any(|&q| q != p && anc[q].contains(&p));
                if reconv {
                    1.0
                } else {
                    0.0
                }
            })
            .collect();
        nodes.push(NodeSpec {
            name: format!("{}:{}", op.id.0, op.kind.name()),
            preds,
            pred_buffer,
            ii,
            tiles_per_inference: tiles as u64,
            is_source: op.kind == OpKind::Input,
        });
    }
    nodes
}

/// Simulated steady-state throughput (inferences/s) of the dataflow
/// schedule for `inferences` back-to-back inferences.
pub fn simulated_throughput(g: &Graph, clock_hz: f64, inferences: u64) -> f64 {
    let nodes = nodes_from_graph(g);
    let report = simulate(&nodes, &SimConfig { inferences, fifo_depth: 4, sequential: false });
    if report.cycles == 0 {
        return 0.0;
    }
    inferences as f64 / (report.cycles as f64 / clock_hz)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::{FormatKind, Precision};
    use crate::ir::{Graph, TensorType};

    fn chain_graph() -> Graph {
        let mut g = Graph::new("chain");
        let x = g.add_input("x", TensorType::fp32(vec![32, 64]));
        let w = g.new_value(
            "w",
            TensorType { shape: vec![64, 64], format: FormatKind::MxInt, precision: Precision::new(5.0, 0.0) },
            None,
        );
        let h = g.add_op(OpKind::Linear, vec![x], vec![w], "h", TensorType::fp32(vec![32, 64]), None);
        let y = g.add_op(OpKind::Gelu, vec![h], vec![], "y", TensorType::fp32(vec![32, 64]), None);
        g.value_mut(h).attrs.tile = (16, 16);
        g.value_mut(y).attrs.tile = (16, 16);
        g.outputs.push(y);
        g
    }

    #[test]
    fn nodes_mirror_ops() {
        let g = chain_graph();
        let nodes = nodes_from_graph(&g);
        assert_eq!(nodes.len(), g.ops.len());
        assert!(nodes[0].is_source);
        assert_eq!(nodes[2].preds, vec![1]);
    }

    #[test]
    fn dataflow_beats_sequential() {
        // The Fig. 1e vs 1f claim: pipelining raises throughput.
        let g = chain_graph();
        let nodes = nodes_from_graph(&g);
        let df = simulate(&nodes, &SimConfig { inferences: 8, fifo_depth: 4, sequential: false });
        let seq = simulate(&nodes, &SimConfig { inferences: 8, fifo_depth: 4, sequential: true });
        assert!(df.cycles < seq.cycles, "dataflow {} vs sequential {}", df.cycles, seq.cycles);
    }

    #[test]
    fn simulator_close_to_regression_model() {
        // Cross-validation: simulated throughput within 2x of the closed
        // form (they differ by fill/drain and stall effects).
        let g = chain_graph();
        let d = crate::hw::Device::u250();
        let reg = crate::hw::throughput::pipeline_throughput(&g, &d);
        let sim = simulated_throughput(&g, d.clock_hz, 16);
        let ratio = sim / reg;
        assert!(ratio > 0.4 && ratio < 2.5, "sim {sim} reg {reg}");
    }
}
