//! The discrete-event core of the dataflow simulator.
//!
//! Time advances in cycles; each node is either idle or busy-until(t).
//! A node fires when every predecessor channel holds at least one tile
//! and every successor channel has space (ready/valid handshake with
//! finite FIFOs). `sequential: true` emulates the non-dataflow schedule
//! of Fig. 1e: a global lock allows only one busy node at a time.
//!
//! ## The beat model (PR 5)
//!
//! Channels have a finite bit-width ([`SimConfig::channel_bits`]) and
//! tiles have a measured packed payload ([`NodeSpec::out_tile_bits`],
//! derived from `packed::packed_bits_for` by the graph lowering). One
//! firing streams its output tile over each successor channel in
//! `beats = ceil(out_tile_bits / channel_bits)` cycles, so the firing
//! occupies `max(ii, beats)` cycles: an under-provisioned channel
//! serializes transfers and stalls the pipeline exactly like a real
//! AXI-stream fabric. `channel_bits = 0` (unbounded) makes every
//! transfer a single beat, `max(ii, 1) = ii` — bit-identical to the
//! pre-beat-model tile simulator.
//!
//! Stall attribution follows the cause: a consumer starved *because its
//! producer is still streaming beats* is not charged; the wait is
//! credited to that channel's [`EdgeReport::transfer_stalled`] counter
//! instead, so per-node stall tables only show genuine compute/back-
//! pressure stalls.

/// Static description of one pipeline node.
#[derive(Debug, Clone)]
pub struct NodeSpec {
    pub name: String,
    /// Indices of predecessor nodes (dataflow edges).
    pub preds: Vec<usize>,
    /// Extra buffer capacity per pred edge, in inference fractions —
    /// the §4.2 "buffer insertion": reconvergent (skip/residual) edges
    /// need a deep buffer or the pipeline deadlocks (one full inference
    /// of credit = double buffering). Same length as `preds`; empty
    /// means all zeros.
    pub pred_buffer: Vec<f64>,
    /// Initiation interval: cycles per tile.
    pub ii: u64,
    /// Tiles this node must emit per inference.
    pub tiles_per_inference: u64,
    /// Sources inject tiles without waiting on predecessors.
    pub is_source: bool,
    /// Measured packed payload of one emitted tile in bits (shared
    /// exponents, guards and word-alignment padding included — see
    /// `packed::packed_bits_for`). 0 means a free interface token:
    /// the transfer always takes a single beat.
    pub out_tile_bits: u64,
}

#[derive(Debug, Clone)]
pub struct SimConfig {
    pub inferences: u64,
    /// FIFO capacity (tiles) on every edge.
    pub fifo_depth: u64,
    /// Non-dataflow (Von Neumann) schedule: one node busy at a time.
    pub sequential: bool,
    /// Handshake channel width in bits. A producer's firing streams its
    /// tile in `ceil(out_tile_bits / channel_bits)` beats and occupies
    /// `max(ii, beats)` cycles. 0 = unbounded (the legacy tile model:
    /// every transfer is one beat and never extends a firing).
    pub channel_bits: u64,
}

impl SimConfig {
    /// Channel width value meaning "unbounded" (legacy tile model).
    pub const UNBOUNDED: u64 = 0;
}

/// Per-channel transfer accounting for one dataflow edge.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EdgeReport {
    /// Producer node index.
    pub producer: usize,
    /// Consumer node index.
    pub consumer: usize,
    /// Input slot on the consumer (index into its `preds`).
    pub slot: usize,
    /// Packed payload bits of one producer tile on this channel.
    pub tile_bits: u64,
    /// Beats one tile needs to cross the channel at the simulated width.
    pub beats_per_tile: u64,
    /// Total beats streamed over this channel (busy channel cycles).
    pub transfer_cycles: u64,
    /// Cycles a ready consumer spent starved on this edge while the
    /// producer was transfer-bound and still streaming — stall cycles
    /// credited to the *channel*, not the consumer node.
    pub transfer_stalled: u64,
}

/// One node firing recorded by [`simulate_traced`]: node `node` fired at
/// cycle `t` and occupied `occupancy = max(ii, beats)` cycles (compute +
/// stream-out). Per node, the sum of occupancies equals
/// [`SimReport::busy`] and the firing count equals
/// `tiles_per_inference * inferences` — the closed forms the trace
/// exporters and `scripts/verify_trace_schema.py` re-derive.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Firing {
    pub node: usize,
    pub t: u64,
    pub occupancy: u64,
}

/// One interval a ready consumer spent starved behind a transfer-bound
/// channel, charged to edge `edge` (index into [`SimReport::edges`]) at
/// cycle `t` for `dt` cycles. Per edge, the `dt`s sum to
/// [`EdgeReport::transfer_stalled`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EdgeStall {
    pub edge: usize,
    pub t: u64,
    pub dt: u64,
}

/// Cycle-accurate event log of one simulation: every firing and every
/// channel-charged stall interval, in deterministic order (time-major;
/// node/edge index within a cycle). Collected by [`simulate_traced`] and
/// rendered as a Perfetto timeline by [`crate::obs::chrome`].
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SimTrace {
    pub firings: Vec<Firing>,
    pub stalls: Vec<EdgeStall>,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SimReport {
    /// Total cycles until the last sink tile.
    pub cycles: u64,
    /// Per-node occupied cycles: computing or streaming an output tile
    /// (utilization = busy / cycles).
    pub busy: Vec<u64>,
    /// Per-node stall cycles spent ready-but-blocked on backpressure or
    /// on a starvation NOT caused by a transfer-bound channel (those are
    /// credited to the channel in [`EdgeReport::transfer_stalled`]).
    /// Counted in absolute cycles: a node blocked across a clock jump
    /// (no firing, time advances to the next busy completion) is
    /// credited the full width of the jump.
    pub stalled: Vec<u64>,
    /// Per-edge channel accounting, in deterministic (consumer, slot)
    /// order.
    pub edges: Vec<EdgeReport>,
}

/// Run the simulation to completion.
///
/// Channels carry *inference fractions*: a producer firing deposits
/// `1/T_p` (its tile as a fraction of one inference), a consumer firing
/// needs `1/T_c`. This lets edges with different tile granularities (the
/// normal case after `parallelize`) rate-match instead of deadlocking.
pub fn simulate(nodes: &[NodeSpec], cfg: &SimConfig) -> SimReport {
    simulate_with(nodes, cfg, None)
}

/// [`simulate`] plus a full [`SimTrace`] event log (every firing, every
/// channel-charged stall interval). The report is bit-identical to the
/// untraced run: tracing only appends to side vectors.
pub fn simulate_traced(nodes: &[NodeSpec], cfg: &SimConfig) -> (SimReport, SimTrace) {
    let mut trace = SimTrace::default();
    let report = simulate_with(nodes, cfg, Some(&mut trace));
    (report, trace)
}

/// Core event loop. `trace`, when present, collects the per-firing /
/// per-stall event log; `None` is the zero-overhead path [`simulate`]
/// takes.
fn simulate_with(
    nodes: &[NodeSpec],
    cfg: &SimConfig,
    mut trace: Option<&mut SimTrace>,
) -> SimReport {
    const EPS: f64 = 1e-9;
    let n = nodes.len();
    // fifo[i][slot] = inference-fraction queued into node i's pred slot
    let mut fifo: Vec<Vec<f64>> = nodes.iter().map(|nd| vec![0.0; nd.preds.len()]).collect();
    // beats one tile of node i needs to cross a channel
    let beats = |i: usize| -> u64 {
        if cfg.channel_bits == SimConfig::UNBOUNDED || nodes[i].out_tile_bits == 0 {
            1
        } else {
            nodes[i].out_tile_bits.div_ceil(cfg.channel_bits)
        }
    };
    // firing occupancy: compute II or transfer serialization, whichever
    // is longer (the channel streams while the next tile computes)
    let occupancy = |i: usize| nodes[i].ii.max(beats(i));
    // a node whose firings are stretched by its channels, not compute
    let transfer_bound = |i: usize| beats(i) > nodes[i].ii;

    // edge table + successor map: (consumer, slot, edge index) per producer
    let mut edges: Vec<EdgeReport> = Vec::new();
    // edge_of[c][slot] = index into `edges`
    let mut edge_of: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut succs: Vec<Vec<(usize, usize, usize)>> = vec![Vec::new(); n];
    for (i, nd) in nodes.iter().enumerate() {
        for (slot, &p) in nd.preds.iter().enumerate() {
            let e = edges.len();
            edges.push(EdgeReport {
                producer: p,
                consumer: i,
                slot,
                tile_bits: nodes[p].out_tile_bits,
                beats_per_tile: beats(p),
                transfer_cycles: 0,
                transfer_stalled: 0,
            });
            edge_of[i].push(e);
            succs[p].push((i, slot, e));
        }
    }

    let frac = |i: usize| 1.0 / nodes[i].tiles_per_inference.max(1) as f64;
    // capacity per edge: `fifo_depth` tiles of the coarser granularity,
    // plus any inserted buffer (reconvergent/skip edges)
    let cap = |p: usize, c: usize, slot: usize| {
        let buf = nodes[c].pred_buffer.get(slot).copied().unwrap_or(0.0);
        cfg.fifo_depth as f64 * frac(p).max(frac(c)) + buf
    };
    let total_tiles: Vec<u64> =
        nodes.iter().map(|nd| nd.tiles_per_inference * cfg.inferences).collect();
    let mut emitted = vec![0u64; n];
    let mut busy_until = vec![0u64; n];
    let mut busy = vec![0u64; n];
    let mut stalled = vec![0u64; n];

    let mut t: u64 = 0;
    let mut blocked = vec![false; n];
    // edges whose channel is charged for a starved consumer this step
    let mut edge_charged = vec![false; edges.len()];
    loop {
        if emitted.iter().zip(total_tiles.iter()).all(|(e, t)| e >= t) {
            break;
        }
        let one_busy = busy_until.iter().any(|&b| b > t);
        let mut fired_any = false;
        blocked.iter_mut().for_each(|b| *b = false);
        edge_charged.iter_mut().for_each(|c| *c = false);
        for i in 0..n {
            if emitted[i] >= total_tiles[i] || busy_until[i] > t {
                continue;
            }
            if cfg.sequential && one_busy {
                continue;
            }
            let need = frac(i);
            let inputs_ok =
                nodes[i].is_source || fifo[i].iter().all(|&q| q + EPS >= need);
            // output space available? (finished consumers stop applying
            // backpressure — their stream is closed)
            let outputs_ok = succs[i].iter().all(|&(c, slot, _)| {
                emitted[c] >= total_tiles[c] || fifo[c][slot] + frac(i) <= cap(i, c, slot) + EPS
            });
            if inputs_ok && outputs_ok {
                // fire: consume, occupy (compute + stream-out), emit
                if !nodes[i].is_source {
                    for q in fifo[i].iter_mut() {
                        *q -= need;
                    }
                }
                let occ = occupancy(i);
                busy_until[i] = t + occ;
                busy[i] += occ;
                emitted[i] += 1;
                if let Some(tr) = trace.as_deref_mut() {
                    tr.firings.push(Firing { node: i, t, occupancy: occ });
                }
                for &(c, slot, e) in &succs[i] {
                    fifo[c][slot] += frac(i);
                    let b = edges[e].beats_per_tile;
                    edges[e].transfer_cycles += b;
                }
                fired_any = true;
                if cfg.sequential {
                    break; // only one firing per scheduling step
                }
            } else if inputs_ok || outputs_ok {
                // Ready-but-blocked. Attribute the wait: a node starved
                // *only* by transfer-bound channels still streaming their
                // producer's tile charges those channels; anything else
                // (backpressure, slow upstream compute) is a genuine
                // node stall, counted as before.
                let starved = |q: f64| q + EPS < need;
                let channel_fault = !inputs_ok
                    && fifo[i].iter().enumerate().all(|(slot, &q)| {
                        let p = nodes[i].preds[slot];
                        !starved(q) || (transfer_bound(p) && busy_until[p] > t)
                    });
                if channel_fault {
                    for (slot, &q) in fifo[i].iter().enumerate() {
                        if starved(q) {
                            edge_charged[edge_of[i][slot]] = true;
                        }
                    }
                } else {
                    blocked[i] = true; // genuine stall: counted below
                }
            }
        }
        // advance: one cycle after a firing, else jump to the next busy
        // completion; a state with no firable node, no busy node, and work
        // remaining is a true handshake deadlock (a wiring bug, not a long
        // pipeline). Ready-but-blocked nodes are credited the FULL width
        // of the advance — a blocked node waits `next - t` real cycles
        // across a jump, not the single scheduling step the old counter
        // recorded (it undercounted stalls by the jump width).
        let dt = if fired_any {
            1
        } else {
            match busy_until.iter().filter(|&&b| b > t).min().copied() {
                Some(next) => next - t,
                None => panic!(
                    "dataflow deadlock at t={t}: emitted={emitted:?}, totals={total_tiles:?}"
                ),
            }
        };
        for i in 0..n {
            if blocked[i] {
                stalled[i] += dt;
            }
        }
        for (e, &charged) in edge_charged.iter().enumerate() {
            if charged {
                edges[e].transfer_stalled += dt;
                if let Some(tr) = trace.as_deref_mut() {
                    tr.stalls.push(EdgeStall { edge: e, t, dt });
                }
            }
        }
        t += dt;
    }
    let cycles = busy_until.iter().copied().max().unwrap_or(t).max(t);
    SimReport { cycles, busy, stalled, edges }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(inferences: u64, fifo_depth: u64, sequential: bool) -> SimConfig {
        SimConfig { inferences, fifo_depth, sequential, channel_bits: SimConfig::UNBOUNDED }
    }

    fn chain(iis: &[u64], tiles: u64) -> Vec<NodeSpec> {
        iis.iter()
            .enumerate()
            .map(|(i, &ii)| NodeSpec {
                name: format!("n{i}"),
                preds: if i == 0 { vec![] } else { vec![i - 1] },
                pred_buffer: vec![],
                ii,
                tiles_per_inference: tiles,
                is_source: i == 0,
                out_tile_bits: 0,
            })
            .collect()
    }

    fn chain_bits(iis: &[u64], tiles: u64, bits: &[u64]) -> Vec<NodeSpec> {
        let mut nodes = chain(iis, tiles);
        for (nd, &b) in nodes.iter_mut().zip(bits.iter()) {
            nd.out_tile_bits = b;
        }
        nodes
    }

    #[test]
    fn single_node_takes_ii_times_tiles() {
        let r = simulate(&chain(&[5], 4), &cfg(1, 2, false));
        assert!(r.cycles >= 5 * 4 && r.cycles <= 5 * 4 + 5, "{}", r.cycles);
    }

    #[test]
    fn pipeline_throughput_set_by_slowest_stage() {
        // stages 1,4,1: steady state ~4 cycles per tile.
        let tiles = 50;
        let r = simulate(&chain(&[1, 4, 1], tiles), &cfg(1, 4, false));
        let per_tile = r.cycles as f64 / tiles as f64;
        assert!(per_tile < 5.0 && per_tile >= 4.0, "{per_tile}");
    }

    #[test]
    fn sequential_is_sum_of_stages() {
        let tiles = 10;
        let df = simulate(&chain(&[2, 2, 2], tiles), &cfg(1, 4, false));
        let seq = simulate(&chain(&[2, 2, 2], tiles), &cfg(1, 4, true));
        // sequential: 3 stages * 2 cycles * 10 tiles = 60; dataflow ~ 24.
        assert!(seq.cycles >= 58, "{}", seq.cycles);
        assert!(df.cycles < seq.cycles / 2, "df {} seq {}", df.cycles, seq.cycles);
    }

    #[test]
    fn deeper_fifos_reduce_stalls() {
        // bursty producer into slow consumer: depth-1 stalls more.
        let nodes = chain(&[1, 6], 40);
        let shallow = simulate(&nodes, &cfg(1, 1, false));
        let deep = simulate(&nodes, &cfg(1, 16, false));
        assert!(deep.stalled[0] < shallow.stalled[0]);
        assert!(deep.cycles <= shallow.cycles);

        // Absolute stall-cycle accounting. With depth 1 the producer
        // (ii=1) fires once per consumer period (ii=6) in steady state
        // and is ready-but-blocked the other ~5 cycles of every period —
        // including the cycles skipped when the clock jumps to the
        // consumer's completion. Over ~38 steady-state periods that is
        // ~190 stall cycles; the pre-fix per-step counter (+1 per
        // scheduling step regardless of jump width) saw only ~2-3 per
        // period. The run lasts ~246 cycles, bounding stalls above.
        assert!(
            shallow.stalled[0] >= 150,
            "stall undercount: producer stalled {} cycles (expected ~190)",
            shallow.stalled[0]
        );
        assert!(
            shallow.stalled[0] <= shallow.cycles,
            "stalls {} exceed total cycles {}",
            shallow.stalled[0],
            shallow.cycles
        );
        // the deep fifo absorbs the first ~16-tile burst: the producer
        // finishes earlier and must stall materially less
        assert!(
            deep.stalled[0] + 50 <= shallow.stalled[0],
            "deep {} vs shallow {}",
            deep.stalled[0],
            shallow.stalled[0]
        );
    }

    #[test]
    fn fork_join_topology() {
        // 0 -> {1, 2} -> 3
        let nodes = vec![
            NodeSpec { name: "src".into(), preds: vec![], pred_buffer: vec![], ii: 1, tiles_per_inference: 20, is_source: true, out_tile_bits: 0 },
            NodeSpec { name: "a".into(), preds: vec![0], pred_buffer: vec![], ii: 2, tiles_per_inference: 20, is_source: false, out_tile_bits: 0 },
            NodeSpec { name: "b".into(), preds: vec![0], pred_buffer: vec![], ii: 3, tiles_per_inference: 20, is_source: false, out_tile_bits: 0 },
            NodeSpec { name: "join".into(), preds: vec![1, 2], pred_buffer: vec![], ii: 1, tiles_per_inference: 20, is_source: false, out_tile_bits: 0 },
        ];
        let r = simulate(&nodes, &cfg(1, 4, false));
        // bounded by the slowest branch (ii=3): ~60 cycles + fill
        assert!(r.cycles >= 60 && r.cycles < 90, "{}", r.cycles);
    }

    #[test]
    fn reconvergent_edge_deadlocks_without_buffer_and_runs_with_it() {
        // 0 -> 1 -> 2(join), and a skip edge 0 -> 2. Node 0 emits many
        // fine tiles; without buffer credit on the skip edge it fills and
        // blocks node 0 before node 2 can start (residual deadlock).
        // src emits 64 fine tiles; mid consumes a quarter-inference per
        // firing (needs 16 src tiles); join consumes fine tiles from BOTH.
        // The skip fifo (4 tiles deep = 1/16 inference) fills long before
        // mid's first output arrives -> src blocks -> deadlock.
        let build = |buf: f64| {
            vec![
                NodeSpec { name: "src".into(), preds: vec![], pred_buffer: vec![], ii: 1, tiles_per_inference: 64, is_source: true, out_tile_bits: 0 },
                NodeSpec { name: "mid".into(), preds: vec![0], pred_buffer: vec![0.0], ii: 16, tiles_per_inference: 4, is_source: false, out_tile_bits: 0 },
                NodeSpec { name: "join".into(), preds: vec![1, 0], pred_buffer: vec![0.0, buf], ii: 1, tiles_per_inference: 64, is_source: false, out_tile_bits: 0 },
            ]
        };
        // with one inference of buffer on the skip edge, it completes
        let ok = simulate(&build(1.0), &cfg(2, 4, false));
        assert!(ok.cycles > 0);
        // without it, it deadlocks (documented failure mode)
        let res = std::panic::catch_unwind(|| simulate(&build(0.0), &cfg(2, 4, false)));
        assert!(res.is_err(), "expected deadlock without buffer insertion");
    }

    #[test]
    fn utilization_of_bottleneck_is_high() {
        let tiles = 100;
        let r = simulate(&chain(&[1, 4, 1], tiles), &cfg(1, 8, false));
        let util = r.busy[1] as f64 / r.cycles as f64;
        assert!(util > 0.9, "bottleneck utilization {util}");
    }

    // ---- beat model ----

    #[test]
    fn unbounded_channel_is_bit_identical_to_huge_channel() {
        // beats collapse to 1 either way: the beat model must degrade to
        // the legacy tile model exactly (cycles, busy, stalls, edges).
        let nodes = chain_bits(&[1, 4, 1], 40, &[256, 512, 128]);
        let unbounded = simulate(&nodes, &cfg(2, 4, false));
        let huge = simulate(
            &nodes,
            &SimConfig { inferences: 2, fifo_depth: 4, sequential: false, channel_bits: 1 << 40 },
        );
        assert_eq!(unbounded, huge);
    }

    #[test]
    fn transfer_beats_extend_firings() {
        // ii=2 but a 256-bit tile over a 32-bit channel needs 8 beats:
        // the single worker's occupancy is max(2, 8) = 8 per tile.
        let nodes = chain_bits(&[2], 10, &[256]);
        // no successor edge: the source's tile still streams out of its
        // write port — occupancy model applies per firing regardless.
        let r = simulate(
            &nodes,
            &SimConfig { inferences: 1, fifo_depth: 4, sequential: false, channel_bits: 32 },
        );
        assert!(r.cycles >= 8 * 10, "{}", r.cycles);
        assert_eq!(r.busy[0], 8 * 10);
    }

    #[test]
    fn halving_channel_width_doubles_transfer_cycles() {
        // payload 1024 bits divides both widths: beats double exactly,
        // and on a transfer-bound pipeline so does the busy time.
        let nodes = chain_bits(&[1, 1], 32, &[1024, 1024]);
        let wide = simulate(
            &nodes,
            &SimConfig { inferences: 2, fifo_depth: 4, sequential: false, channel_bits: 64 },
        );
        let narrow = simulate(
            &nodes,
            &SimConfig { inferences: 2, fifo_depth: 4, sequential: false, channel_bits: 32 },
        );
        assert_eq!(wide.edges.len(), 1);
        assert_eq!(wide.edges[0].beats_per_tile, 16);
        assert_eq!(narrow.edges[0].beats_per_tile, 32);
        assert_eq!(narrow.edges[0].transfer_cycles, 2 * wide.edges[0].transfer_cycles);
        assert!(
            narrow.cycles as f64 >= 1.8 * wide.cycles as f64,
            "narrow {} vs wide {}",
            narrow.cycles,
            wide.cycles
        );
    }

    #[test]
    fn remainder_payload_rounds_beats_up() {
        // 100 bits over a 64-bit channel: 2 beats, not 1.5.
        let nodes = chain_bits(&[1, 1], 8, &[100, 0]);
        let r = simulate(
            &nodes,
            &SimConfig { inferences: 1, fifo_depth: 4, sequential: false, channel_bits: 64 },
        );
        assert_eq!(r.edges[0].beats_per_tile, 2);
        // zero-payload interface tokens stay single-beat
        let nodes0 = chain_bits(&[1, 1], 8, &[0, 0]);
        let r0 = simulate(
            &nodes0,
            &SimConfig { inferences: 1, fifo_depth: 4, sequential: false, channel_bits: 64 },
        );
        assert_eq!(r0.edges[0].beats_per_tile, 1);
    }

    #[test]
    fn starvation_behind_slow_channel_is_credited_to_the_edge() {
        // src streams 256-bit tiles over a 32-bit channel (8 beats, ii=1:
        // transfer-bound). The sink (ii=1) idles ~7 of every 8 cycles —
        // that wait belongs to the channel, not the sink's stall column.
        let nodes = chain_bits(&[1, 1], 64, &[256, 0]);
        let r = simulate(
            &nodes,
            &SimConfig { inferences: 1, fifo_depth: 4, sequential: false, channel_bits: 32 },
        );
        let e = &r.edges[0];
        assert_eq!((e.producer, e.consumer, e.slot), (0, 1, 0));
        assert!(
            e.transfer_stalled >= 64 * 6,
            "channel under-credited: {} (expected ~{} cycles)",
            e.transfer_stalled,
            64 * 7
        );
        assert!(
            r.stalled[1] <= 8,
            "sink charged {} stall cycles that belong to the channel",
            r.stalled[1]
        );
    }

    // ---- trace collection ----

    #[test]
    fn traced_run_matches_untraced_report() {
        let nodes = chain_bits(&[1, 4, 1], 20, &[256, 512, 128]);
        let c = SimConfig { inferences: 2, fifo_depth: 4, sequential: false, channel_bits: 64 };
        let plain = simulate(&nodes, &c);
        let (traced, _) = simulate_traced(&nodes, &c);
        assert_eq!(plain, traced);
    }

    #[test]
    fn trace_firings_sum_to_closed_form_accounting() {
        // Per node: firing count == tiles*inferences, occupancy sum ==
        // busy[i], and the last firing's completion == report.cycles.
        // These are the invariants the Chrome exporter and the python
        // mirror (scripts/verify_trace_schema.py) re-derive.
        let nodes = chain_bits(&[1, 4, 1], 20, &[256, 512, 128]);
        let c = SimConfig { inferences: 2, fifo_depth: 4, sequential: false, channel_bits: 32 };
        let (r, tr) = simulate_traced(&nodes, &c);
        for i in 0..nodes.len() {
            let fires: Vec<_> = tr.firings.iter().filter(|f| f.node == i).collect();
            assert_eq!(fires.len() as u64, nodes[i].tiles_per_inference * c.inferences);
            assert_eq!(fires.iter().map(|f| f.occupancy).sum::<u64>(), r.busy[i]);
        }
        let end = tr.firings.iter().map(|f| f.t + f.occupancy).max().unwrap();
        assert_eq!(end, r.cycles);
        // time-major order within the log
        assert!(tr.firings.windows(2).all(|w| w[0].t <= w[1].t));
    }

    #[test]
    fn trace_stalls_sum_to_edge_report() {
        let nodes = chain_bits(&[1, 1], 64, &[256, 0]);
        let c = SimConfig { inferences: 1, fifo_depth: 4, sequential: false, channel_bits: 32 };
        let (r, tr) = simulate_traced(&nodes, &c);
        for (e, edge) in r.edges.iter().enumerate() {
            let total: u64 = tr.stalls.iter().filter(|s| s.edge == e).map(|s| s.dt).sum();
            assert_eq!(total, edge.transfer_stalled, "edge {e}");
        }
        assert!(!tr.stalls.is_empty(), "starved fabric must log stall intervals");
    }

    #[test]
    fn compute_starvation_still_charges_the_consumer() {
        // Slow *compute* upstream (ii=8, single-beat transfers): the
        // consumer's wait is a genuine pipeline stall, charged as before.
        let nodes = chain_bits(&[8, 1], 32, &[0, 0]);
        let r = simulate(
            &nodes,
            &SimConfig { inferences: 1, fifo_depth: 4, sequential: false, channel_bits: 32 },
        );
        assert!(r.stalled[1] > 100, "consumer stall expected, got {}", r.stalled[1]);
        assert_eq!(r.edges[0].transfer_stalled, 0);
    }
}
