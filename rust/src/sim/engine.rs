//! The discrete-event core of the dataflow simulator.
//!
//! Time advances in cycles; each node is either idle or busy-until(t).
//! A node fires when every predecessor channel holds at least one tile
//! and every successor channel has space (ready/valid handshake with
//! finite FIFOs). `sequential: true` emulates the non-dataflow schedule
//! of Fig. 1e: a global lock allows only one busy node at a time.

/// Static description of one pipeline node.
#[derive(Debug, Clone)]
pub struct NodeSpec {
    pub name: String,
    /// Indices of predecessor nodes (dataflow edges).
    pub preds: Vec<usize>,
    /// Extra buffer capacity per pred edge, in inference fractions —
    /// the §4.2 "buffer insertion": reconvergent (skip/residual) edges
    /// need a deep buffer or the pipeline deadlocks (one full inference
    /// of credit = double buffering). Same length as `preds`; empty
    /// means all zeros.
    pub pred_buffer: Vec<f64>,
    /// Initiation interval: cycles per tile.
    pub ii: u64,
    /// Tiles this node must emit per inference.
    pub tiles_per_inference: u64,
    /// Sources inject tiles without waiting on predecessors.
    pub is_source: bool,
}

#[derive(Debug, Clone)]
pub struct SimConfig {
    pub inferences: u64,
    /// FIFO capacity (tiles) on every edge.
    pub fifo_depth: u64,
    /// Non-dataflow (Von Neumann) schedule: one node busy at a time.
    pub sequential: bool,
}

#[derive(Debug, Clone)]
pub struct SimReport {
    /// Total cycles until the last sink tile.
    pub cycles: u64,
    /// Per-node busy cycles (utilization = busy / cycles).
    pub busy: Vec<u64>,
    /// Per-node stall cycles spent ready-but-blocked on backpressure.
    /// Counted in absolute cycles: a node blocked across a clock jump
    /// (no firing, time advances to the next busy completion) is
    /// credited the full width of the jump.
    pub stalled: Vec<u64>,
}

/// Run the simulation to completion.
///
/// Channels carry *inference fractions*: a producer firing deposits
/// `1/T_p` (its tile as a fraction of one inference), a consumer firing
/// needs `1/T_c`. This lets edges with different tile granularities (the
/// normal case after `parallelize`) rate-match instead of deadlocking.
pub fn simulate(nodes: &[NodeSpec], cfg: &SimConfig) -> SimReport {
    const EPS: f64 = 1e-9;
    let n = nodes.len();
    // fifo[i][slot] = inference-fraction queued into node i's pred slot
    let mut fifo: Vec<Vec<f64>> = nodes.iter().map(|nd| vec![0.0; nd.preds.len()]).collect();
    // successor map: (consumer, slot) pairs per producer
    let mut succs: Vec<Vec<(usize, usize)>> = vec![Vec::new(); n];
    for (i, nd) in nodes.iter().enumerate() {
        for (slot, &p) in nd.preds.iter().enumerate() {
            succs[p].push((i, slot));
        }
    }
    let frac = |i: usize| 1.0 / nodes[i].tiles_per_inference.max(1) as f64;
    // capacity per edge: `fifo_depth` tiles of the coarser granularity,
    // plus any inserted buffer (reconvergent/skip edges)
    let cap = |p: usize, c: usize, slot: usize| {
        let buf = nodes[c].pred_buffer.get(slot).copied().unwrap_or(0.0);
        cfg.fifo_depth as f64 * frac(p).max(frac(c)) + buf
    };
    let total_tiles: Vec<u64> =
        nodes.iter().map(|nd| nd.tiles_per_inference * cfg.inferences).collect();
    let mut emitted = vec![0u64; n];
    let mut busy_until = vec![0u64; n];
    let mut busy = vec![0u64; n];
    let mut stalled = vec![0u64; n];

    let mut t: u64 = 0;
    let mut blocked = vec![false; n];
    loop {
        if emitted.iter().zip(total_tiles.iter()).all(|(e, t)| e >= t) {
            break;
        }
        let one_busy = busy_until.iter().any(|&b| b > t);
        let mut fired_any = false;
        blocked.iter_mut().for_each(|b| *b = false);
        for i in 0..n {
            if emitted[i] >= total_tiles[i] || busy_until[i] > t {
                continue;
            }
            if cfg.sequential && one_busy {
                continue;
            }
            let need = frac(i);
            let inputs_ok =
                nodes[i].is_source || fifo[i].iter().all(|&q| q + EPS >= need);
            // output space available? (finished consumers stop applying
            // backpressure — their stream is closed)
            let outputs_ok = succs[i].iter().all(|&(c, slot)| {
                emitted[c] >= total_tiles[c] || fifo[c][slot] + frac(i) <= cap(i, c, slot) + EPS
            });
            if inputs_ok && outputs_ok {
                // fire: consume, occupy, emit
                if !nodes[i].is_source {
                    for q in fifo[i].iter_mut() {
                        *q -= need;
                    }
                }
                busy_until[i] = t + nodes[i].ii;
                busy[i] += nodes[i].ii;
                emitted[i] += 1;
                for &(c, slot) in &succs[i] {
                    fifo[c][slot] += frac(i);
                }
                fired_any = true;
                if cfg.sequential {
                    break; // only one firing per scheduling step
                }
            } else if inputs_ok || outputs_ok {
                blocked[i] = true; // ready-but-blocked: stall cycles below
            }
        }
        // advance: one cycle after a firing, else jump to the next busy
        // completion; a state with no firable node, no busy node, and work
        // remaining is a true handshake deadlock (a wiring bug, not a long
        // pipeline). Ready-but-blocked nodes are credited the FULL width
        // of the advance — a blocked node waits `next - t` real cycles
        // across a jump, not the single scheduling step the old counter
        // recorded (it undercounted stalls by the jump width).
        let dt = if fired_any {
            1
        } else {
            match busy_until.iter().filter(|&&b| b > t).min().copied() {
                Some(next) => next - t,
                None => panic!(
                    "dataflow deadlock at t={t}: emitted={emitted:?}, totals={total_tiles:?}"
                ),
            }
        };
        for i in 0..n {
            if blocked[i] {
                stalled[i] += dt;
            }
        }
        t += dt;
    }
    let cycles = busy_until.iter().copied().max().unwrap_or(t).max(t);
    SimReport { cycles, busy, stalled }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chain(iis: &[u64], tiles: u64) -> Vec<NodeSpec> {
        iis.iter()
            .enumerate()
            .map(|(i, &ii)| NodeSpec {
                name: format!("n{i}"),
                preds: if i == 0 { vec![] } else { vec![i - 1] },
                pred_buffer: vec![],
                ii,
                tiles_per_inference: tiles,
                is_source: i == 0,
            })
            .collect()
    }

    #[test]
    fn single_node_takes_ii_times_tiles() {
        let r = simulate(&chain(&[5], 4), &SimConfig { inferences: 1, fifo_depth: 2, sequential: false });
        assert!(r.cycles >= 5 * 4 && r.cycles <= 5 * 4 + 5, "{}", r.cycles);
    }

    #[test]
    fn pipeline_throughput_set_by_slowest_stage() {
        // stages 1,4,1: steady state ~4 cycles per tile.
        let tiles = 50;
        let r = simulate(&chain(&[1, 4, 1], tiles), &SimConfig { inferences: 1, fifo_depth: 4, sequential: false });
        let per_tile = r.cycles as f64 / tiles as f64;
        assert!(per_tile < 5.0 && per_tile >= 4.0, "{per_tile}");
    }

    #[test]
    fn sequential_is_sum_of_stages() {
        let tiles = 10;
        let df = simulate(&chain(&[2, 2, 2], tiles), &SimConfig { inferences: 1, fifo_depth: 4, sequential: false });
        let seq = simulate(&chain(&[2, 2, 2], tiles), &SimConfig { inferences: 1, fifo_depth: 4, sequential: true });
        // sequential: 3 stages * 2 cycles * 10 tiles = 60; dataflow ~ 24.
        assert!(seq.cycles >= 58, "{}", seq.cycles);
        assert!(df.cycles < seq.cycles / 2, "df {} seq {}", df.cycles, seq.cycles);
    }

    #[test]
    fn deeper_fifos_reduce_stalls() {
        // bursty producer into slow consumer: depth-1 stalls more.
        let nodes = chain(&[1, 6], 40);
        let shallow = simulate(&nodes, &SimConfig { inferences: 1, fifo_depth: 1, sequential: false });
        let deep = simulate(&nodes, &SimConfig { inferences: 1, fifo_depth: 16, sequential: false });
        assert!(deep.stalled[0] < shallow.stalled[0]);
        assert!(deep.cycles <= shallow.cycles);

        // Absolute stall-cycle accounting. With depth 1 the producer
        // (ii=1) fires once per consumer period (ii=6) in steady state
        // and is ready-but-blocked the other ~5 cycles of every period —
        // including the cycles skipped when the clock jumps to the
        // consumer's completion. Over ~38 steady-state periods that is
        // ~190 stall cycles; the pre-fix per-step counter (+1 per
        // scheduling step regardless of jump width) saw only ~2-3 per
        // period. The run lasts ~246 cycles, bounding stalls above.
        assert!(
            shallow.stalled[0] >= 150,
            "stall undercount: producer stalled {} cycles (expected ~190)",
            shallow.stalled[0]
        );
        assert!(
            shallow.stalled[0] <= shallow.cycles,
            "stalls {} exceed total cycles {}",
            shallow.stalled[0],
            shallow.cycles
        );
        // the deep fifo absorbs the first ~16-tile burst: the producer
        // finishes earlier and must stall materially less
        assert!(
            deep.stalled[0] + 50 <= shallow.stalled[0],
            "deep {} vs shallow {}",
            deep.stalled[0],
            shallow.stalled[0]
        );
    }

    #[test]
    fn fork_join_topology() {
        // 0 -> {1, 2} -> 3
        let nodes = vec![
            NodeSpec { name: "src".into(), preds: vec![], pred_buffer: vec![], ii: 1, tiles_per_inference: 20, is_source: true },
            NodeSpec { name: "a".into(), preds: vec![0], pred_buffer: vec![], ii: 2, tiles_per_inference: 20, is_source: false },
            NodeSpec { name: "b".into(), preds: vec![0], pred_buffer: vec![], ii: 3, tiles_per_inference: 20, is_source: false },
            NodeSpec { name: "join".into(), preds: vec![1, 2], pred_buffer: vec![], ii: 1, tiles_per_inference: 20, is_source: false },
        ];
        let r = simulate(&nodes, &SimConfig { inferences: 1, fifo_depth: 4, sequential: false });
        // bounded by the slowest branch (ii=3): ~60 cycles + fill
        assert!(r.cycles >= 60 && r.cycles < 90, "{}", r.cycles);
    }

    #[test]
    fn reconvergent_edge_deadlocks_without_buffer_and_runs_with_it() {
        // 0 -> 1 -> 2(join), and a skip edge 0 -> 2. Node 0 emits many
        // fine tiles; without buffer credit on the skip edge it fills and
        // blocks node 0 before node 2 can start (residual deadlock).
        // src emits 64 fine tiles; mid consumes a quarter-inference per
        // firing (needs 16 src tiles); join consumes fine tiles from BOTH.
        // The skip fifo (4 tiles deep = 1/16 inference) fills long before
        // mid's first output arrives -> src blocks -> deadlock.
        let build = |buf: f64| {
            vec![
                NodeSpec { name: "src".into(), preds: vec![], pred_buffer: vec![], ii: 1, tiles_per_inference: 64, is_source: true },
                NodeSpec { name: "mid".into(), preds: vec![0], pred_buffer: vec![0.0], ii: 16, tiles_per_inference: 4, is_source: false },
                NodeSpec { name: "join".into(), preds: vec![1, 0], pred_buffer: vec![0.0, buf], ii: 1, tiles_per_inference: 64, is_source: false },
            ]
        };
        // with one inference of buffer on the skip edge, it completes
        let ok = simulate(&build(1.0), &SimConfig { inferences: 2, fifo_depth: 4, sequential: false });
        assert!(ok.cycles > 0);
        // without it, it deadlocks (documented failure mode)
        let res = std::panic::catch_unwind(|| {
            simulate(&build(0.0), &SimConfig { inferences: 2, fifo_depth: 4, sequential: false })
        });
        assert!(res.is_err(), "expected deadlock without buffer insertion");
    }

    #[test]
    fn utilization_of_bottleneck_is_high() {
        let tiles = 100;
        let r = simulate(&chain(&[1, 4, 1], tiles), &SimConfig { inferences: 1, fifo_depth: 8, sequential: false });
        let util = r.busy[1] as f64 / r.cycles as f64;
        assert!(util > 0.9, "bottleneck utilization {util}");
    }
}
