//! Memory allocation for model parameters (§4.2, "Memory Allocation"):
//! greedily pin the hottest weight tensors on-chip until the URAM/BRAM
//! budget is spent; the rest stream from off-chip and consume bandwidth,
//! which can cap the achievable pipeline throughput.
//!
//! Tensors are priced with the *measured* packed storage
//! ([`crate::packed::layout::packed_bits_for`]): shared-exponent bytes,
//! BMF guard / BL zero bits and word-alignment padding included — not
//! the idealized analytic `ty.bits()` of Eq. (1). For MXInt at 8-bit
//! elements the two agree exactly; for the other block formats the
//! measured number is the honest (slightly larger) one.
//!
//! The same oracle prices the *on-fabric* traffic: per-tile edge
//! payloads in [`crate::hw::throughput::op_tile_bits`] (the beat model)
//! are `packed_bits_for` over the tile shape, so off-chip spill bits
//! here and channel beats there are two views of one measured layout —
//! they cannot drift apart.

use super::Device;
use crate::ir::Graph;
use crate::packed::layout::packed_bits_for;

/// Allocation decision for one parameter tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamPlacement {
    pub value_name: String,
    /// Measured packed storage bits (see module docs).
    pub bits: f64,
    /// Reuse count per inference (how many tiles stream past it).
    pub reuse: f64,
    pub onchip: bool,
}

/// Plan placements: sort by reuse-per-bit (hotness density) and fill the
/// on-chip budget.
pub fn plan(g: &Graph, device: &Device) -> Vec<ParamPlacement> {
    let mut params: Vec<ParamPlacement> = Vec::new();
    for op in &g.ops {
        for &p in &op.params {
            let v = g.value(p);
            let bits = packed_bits_for(v.ty.format, v.ty.precision, &v.ty.shape) as f64;
            // A weight is re-read once per streaming tile of the output.
            let out = op.results.first().map(|&r| g.value(r)).unwrap();
            let tile = out.attrs.tile.0.max(1) * out.attrs.tile.1.max(1);
            let reuse = (out.ty.elements() as f64 / tile as f64).max(1.0);
            params.push(ParamPlacement { value_name: v.name.clone(), bits, reuse, onchip: false });
        }
    }
    // total_cmp: the key is a quotient of model outputs, and a NaN from a
    // degenerate tensor (zero-size shape, poisoned precision knob) must
    // sort deterministically instead of panicking in partial_cmp.
    params.sort_by(|a, b| {
        let ka = a.reuse / a.bits.max(1.0);
        let kb = b.reuse / b.bits.max(1.0);
        kb.total_cmp(&ka)
    });
    let mut budget = device.onchip_bits;
    for p in params.iter_mut() {
        if p.bits <= budget {
            p.onchip = true;
            budget -= p.bits;
        }
    }
    params
}

/// Total off-chip parameter traffic per inference (bits).
pub fn offchip_bits_per_inference(placements: &[ParamPlacement]) -> f64 {
    placements.iter().filter(|p| !p.onchip).map(|p| p.bits).sum()
}

/// Throughput cap from off-chip bandwidth (inferences/s).
pub fn bandwidth_cap(placements: &[ParamPlacement], device: &Device) -> f64 {
    let bits = offchip_bits_per_inference(placements);
    if bits <= 0.0 {
        f64::INFINITY
    } else {
        device.offchip_bits_per_s / bits
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::{FormatKind, Precision};
    use crate::ir::{OpKind, TensorType};

    fn two_weight_graph() -> Graph {
        let mut g = Graph::new("m");
        let x = g.add_input("x", TensorType::fp32(vec![32, 64]));
        let mk = |g: &mut Graph, n: &str, shape: Vec<usize>| {
            g.new_value(
                n,
                TensorType { shape, format: FormatKind::MxInt, precision: Precision::new(7.0, 0.0) },
                None,
            )
        };
        let w1 = mk(&mut g, "w1", vec![64, 64]);
        let h = g.add_op(OpKind::Linear, vec![x], vec![w1], "h", TensorType::fp32(vec![32, 64]), None);
        let w2 = mk(&mut g, "w2", vec![64, 256]);
        let y = g.add_op(OpKind::Linear, vec![h], vec![w2], "y", TensorType::fp32(vec![32, 256]), None);
        g.outputs.push(y);
        g
    }

    #[test]
    fn everything_fits_on_big_device() {
        let g = two_weight_graph();
        let pl = plan(&g, &Device::u250());
        assert!(pl.iter().all(|p| p.onchip));
        assert_eq!(offchip_bits_per_inference(&pl), 0.0);
        assert_eq!(bandwidth_cap(&pl, &Device::u250()), f64::INFINITY);
    }

    #[test]
    fn tiny_budget_spills() {
        let g = two_weight_graph();
        let mut d = Device::u250();
        d.onchip_bits = 64.0 * 64.0 * 8.25; // room for w1 only
        let pl = plan(&g, &d);
        assert!(pl.iter().any(|p| p.onchip));
        assert!(pl.iter().any(|p| !p.onchip));
        assert!(offchip_bits_per_inference(&pl) > 0.0);
        assert!(bandwidth_cap(&pl, &d).is_finite());
    }

    #[test]
    fn hotter_tensors_first() {
        let g = two_weight_graph();
        let pl = plan(&g, &Device::u250());
        // sorted by reuse density descending
        for w in pl.windows(2) {
            let ka = w[0].reuse / w[0].bits;
            let kb = w[1].reuse / w[1].bits;
            assert!(ka >= kb);
        }
    }

    #[test]
    fn bits_are_measured_packed_storage() {
        let g = two_weight_graph();
        let pl = plan(&g, &Device::u250());
        let w1 = pl.iter().find(|p| p.value_name == "w1").unwrap();
        // MXInt m=7: 8-bit elements pack padding-free, so measured ==
        // analytic Eq. (1) == 64*64*8.25 — and both equal what actually
        // packing a tensor of that shape occupies.
        assert_eq!(w1.bits, 64.0 * 64.0 * 8.25);
        let data = vec![1.0f32; 64 * 64];
        let t = crate::packed::layout::pack(
            &data,
            64,
            64,
            FormatKind::MxInt,
            Precision::new(7.0, 0.0),
        );
        assert_eq!(w1.bits, t.storage_bits() as f64);
    }

    #[test]
    fn offchip_pricing_and_edge_payloads_share_one_oracle() {
        // A weight streamed from off-chip in whole-tensor "tiles" must
        // cost exactly the bits the beat model charges the edge — both
        // are packed_bits_for over the same shape.
        let g = two_weight_graph();
        let pl = plan(&g, &Device::u250());
        let w1 = pl.iter().find(|p| p.value_name == "w1").unwrap();
        let v = g.values.iter().find(|v| v.name == "w1").unwrap();
        let (r, c) = (v.ty.shape[0], v.ty.shape[1]);
        assert_eq!(
            w1.bits,
            crate::packed::packed_bits_for(v.ty.format, v.ty.precision, &[r, c]) as f64
        );
    }

    #[test]
    fn degenerate_params_plan_without_panicking() {
        // Regression: the old sort used partial_cmp().unwrap() on
        // reuse/bits and could panic on degenerate tensors. Zero-element
        // shapes and NaN precision knobs must plan deterministically.
        let mut g = Graph::new("degenerate");
        let x = g.add_input("x", TensorType::fp32(vec![32, 64]));
        let w0 = g.new_value(
            "w_empty",
            TensorType {
                shape: vec![0, 2],
                format: FormatKind::MxInt,
                precision: Precision::new(7.0, 0.0),
            },
            None,
        );
        let h = g.add_op(OpKind::Linear, vec![x], vec![w0], "h", TensorType::fp32(vec![0, 2]), None);
        let w1 = g.new_value(
            "w_nan_knob",
            TensorType {
                shape: vec![64, 64],
                format: FormatKind::MxInt,
                precision: Precision::new(f32::NAN, 0.0),
            },
            None,
        );
        let y = g.add_op(OpKind::Linear, vec![h], vec![w1], "y", TensorType::fp32(vec![32, 64]), None);
        g.outputs.push(y);
        let d = Device::u250();
        let pl1 = plan(&g, &d);
        let pl2 = plan(&g, &d);
        assert_eq!(pl1, pl2, "degenerate plan must be deterministic");
        let empty = pl1.iter().find(|p| p.value_name == "w_empty").unwrap();
        assert_eq!(empty.bits, 0.0, "zero-element tensor costs nothing");
        assert!(offchip_bits_per_inference(&pl1).is_finite());
    }
}
