//! Memory allocation for model parameters (§4.2, "Memory Allocation"):
//! greedily pin the hottest weight tensors on-chip until the URAM/BRAM
//! budget is spent; the rest stream from off-chip and consume bandwidth,
//! which can cap the achievable pipeline throughput.

use super::Device;
use crate::ir::Graph;

/// Allocation decision for one parameter tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamPlacement {
    pub value_name: String,
    pub bits: f64,
    /// Reuse count per inference (how many tiles stream past it).
    pub reuse: f64,
    pub onchip: bool,
}

/// Plan placements: sort by reuse-per-bit (hotness density) and fill the
/// on-chip budget.
pub fn plan(g: &Graph, device: &Device) -> Vec<ParamPlacement> {
    let mut params: Vec<ParamPlacement> = Vec::new();
    for op in &g.ops {
        for &p in &op.params {
            let v = g.value(p);
            let bits = v.ty.bits();
            // A weight is re-read once per streaming tile of the output.
            let out = op.results.first().map(|&r| g.value(r)).unwrap();
            let tile = out.attrs.tile.0.max(1) * out.attrs.tile.1.max(1);
            let reuse = (out.ty.elements() as f64 / tile as f64).max(1.0);
            params.push(ParamPlacement { value_name: v.name.clone(), bits, reuse, onchip: false });
        }
    }
    params.sort_by(|a, b| {
        let ka = a.reuse / a.bits.max(1.0);
        let kb = b.reuse / b.bits.max(1.0);
        kb.partial_cmp(&ka).unwrap()
    });
    let mut budget = device.onchip_bits;
    for p in params.iter_mut() {
        if p.bits <= budget {
            p.onchip = true;
            budget -= p.bits;
        }
    }
    params
}

/// Total off-chip parameter traffic per inference (bits).
pub fn offchip_bits_per_inference(placements: &[ParamPlacement]) -> f64 {
    placements.iter().filter(|p| !p.onchip).map(|p| p.bits).sum()
}

/// Throughput cap from off-chip bandwidth (inferences/s).
pub fn bandwidth_cap(placements: &[ParamPlacement], device: &Device) -> f64 {
    let bits = offchip_bits_per_inference(placements);
    if bits <= 0.0 {
        f64::INFINITY
    } else {
        device.offchip_bits_per_s / bits
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::{FormatKind, Precision};
    use crate::ir::{OpKind, TensorType};

    fn two_weight_graph() -> Graph {
        let mut g = Graph::new("m");
        let x = g.add_input("x", TensorType::fp32(vec![32, 64]));
        let mk = |g: &mut Graph, n: &str, shape: Vec<usize>| {
            g.new_value(
                n,
                TensorType { shape, format: FormatKind::MxInt, precision: Precision::new(7.0, 0.0) },
                None,
            )
        };
        let w1 = mk(&mut g, "w1", vec![64, 64]);
        let h = g.add_op(OpKind::Linear, vec![x], vec![w1], "h", TensorType::fp32(vec![32, 64]), None);
        let w2 = mk(&mut g, "w2", vec![64, 256]);
        let y = g.add_op(OpKind::Linear, vec![h], vec![w2], "y", TensorType::fp32(vec![32, 256]), None);
        g.outputs.push(y);
        g
    }

    #[test]
    fn everything_fits_on_big_device() {
        let g = two_weight_graph();
        let pl = plan(&g, &Device::u250());
        assert!(pl.iter().all(|p| p.onchip));
        assert_eq!(offchip_bits_per_inference(&pl), 0.0);
        assert_eq!(bandwidth_cap(&pl, &Device::u250()), f64::INFINITY);
    }

    #[test]
    fn tiny_budget_spills() {
        let g = two_weight_graph();
        let mut d = Device::u250();
        d.onchip_bits = 64.0 * 64.0 * 8.25; // room for w1 only
        let pl = plan(&g, &d);
        assert!(pl.iter().any(|p| p.onchip));
        assert!(pl.iter().any(|p| !p.onchip));
        assert!(offchip_bits_per_inference(&pl) > 0.0);
        assert!(bandwidth_cap(&pl, &d).is_finite());
    }

    #[test]
    fn hotter_tensors_first() {
        let g = two_weight_graph();
        let pl = plan(&g, &Device::u250());
        // sorted by reuse density descending
        for w in pl.windows(2) {
            let ka = w[0].reuse / w[0].bits;
            let kb = w[1].reuse / w[1].bits;
            assert!(ka >= kb);
        }
    }
}
