//! Structural area models for the hardware operator library (LUT-
//! equivalents), calibrated to the paper's Table 1 densities at the 8-bit
//! anchor configurations (see `hw::tests`).
//!
//! Structure follows the dot-product operators of Fig. 3 (right):
//!  * fixed point: w^2 multiplier + accumulator;
//!  * float: mantissa multiplier + exponent adder + *dynamic shifter* (the
//!    dominant cost per Coward et al. [10]);
//!  * MXInt: integer mantissa datapath + amortized per-block shared-
//!    exponent unit — no per-element dynamic shift (the area win);
//!  * BMF: MXInt-like + small local shifter for the element exponent;
//!  * BL: no mantissa multiplier at all — exponent adder + shift-into-
//!    accumulator.

use super::Device;
use crate::formats::{FormatKind, Precision, BLOCK_SHAPE};
use crate::ir::OpKind;

/// Amortized per-element cost of the block-shared exponent unit: an 8-bit
/// exponent adder plus the max-reduction tree, divided over the block.
fn block_overhead() -> f64 {
    40.0 / (BLOCK_SHAPE.0 * BLOCK_SHAPE.1) as f64
}

/// Un-calibrated structural LUT cost of one MAC.
fn structural(fmt: FormatKind, p: Precision) -> f64 {
    let m = p.bits.max(1.0) as f64; // format-specific meaning, see Precision
    match fmt {
        FormatKind::Fp32 => float_structural(8.0, 23.0),
        FormatKind::Fp8 => float_structural(4.0, 3.0),
        FormatKind::Int => m * m + 2.0 * m,
        FormatKind::MxInt => {
            let w = m + 1.0; // sign+mantissa datapath
            w * w + 2.0 * w + block_overhead()
        }
        FormatKind::Bmf => {
            let w = m + 1.0;
            let e_loc = crate::formats::bmf::LOCAL_EXP_BITS as f64;
            w * w + 2.0 * w + w * e_loc + block_overhead()
        }
        FormatKind::Bl => {
            let e = m; // element exponent bits
            // exponent adder + dynamic shift into a 16-bit accumulator
            e + 3.0 * e + 16.0 + block_overhead()
        }
    }
}

fn float_structural(e: f64, m: f64) -> f64 {
    let w = m + 1.0;
    w * w + 2.0 * w + 3.0 * e + w * e / 2.0
}

/// FP32 MAC anchor in LUT-equivalents.
const FP32_MAC_LUTS: f64 = 800.0;

/// Table 1 arithmetic-density anchors (area = FP32 / density at the 8-bit
/// element configuration of each format).
fn calibration(fmt: FormatKind) -> f64 {
    let (anchor_density, anchor_p) = match fmt {
        FormatKind::Fp32 => (1.0, Precision::new(32.0, 0.0)),
        FormatKind::Int => (7.7, Precision::new(8.0, 4.0)),
        FormatKind::Fp8 => (17.4, Precision::new(8.0, 0.0)),
        FormatKind::MxInt => (14.4, Precision::new(7.0, 0.0)),
        FormatKind::Bmf => (14.4, Precision::new(5.0, 0.0)),
        FormatKind::Bl => (16.1, Precision::new(7.0, 0.0)),
    };
    (FP32_MAC_LUTS / anchor_density) / structural(fmt, anchor_p)
}

/// Calibrated LUT cost of one MAC in `fmt` at precision `p`.
pub fn mac_area_luts(fmt: FormatKind, p: Precision) -> f64 {
    calibration(fmt) * structural(fmt, p)
}

/// Area of a whole dataflow operator instantiated with streaming tile
/// `tile` (rows x cols of parallel lanes). GEMM-class ops scale with the
/// MAC array; fixed-function ops scale with lanes.
pub fn op_area_luts(kind: OpKind, fmt: FormatKind, p: Precision, tile: (usize, usize)) -> f64 {
    let lanes = (tile.0 * tile.1) as f64;
    let ctrl = 150.0; // handshake FSM + counters per operator
    match kind {
        OpKind::Linear | OpKind::Attention => lanes * mac_area_luts(fmt, p) + ctrl,
        // Embedding: a wide ROM mux per lane (no MACs).
        OpKind::Embed => lanes * 24.0 + ctrl,
        OpKind::LayerNorm => lanes * 450.0 + ctrl,
        OpKind::Softmax => lanes * 600.0 + ctrl,
        OpKind::Gelu => lanes * 300.0 + ctrl,
        OpKind::Add => lanes * 30.0 + ctrl,
        OpKind::MeanPool => lanes * 40.0 + ctrl,
        // Stream-order switches: line buffers + muxing.
        OpKind::Transpose | OpKind::Reorder => lanes * 12.0 + ctrl,
        OpKind::Input | OpKind::Output => ctrl,
    }
}

/// Fraction of the device the design occupies.
pub fn utilization(total_luts: f64, device: &Device) -> f64 {
    total_luts / device.luts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mac_area_monotone_in_mantissa() {
        let a2 = mac_area_luts(FormatKind::MxInt, Precision::new(2.0, 0.0));
        let a4 = mac_area_luts(FormatKind::MxInt, Precision::new(4.0, 0.0));
        let a7 = mac_area_luts(FormatKind::MxInt, Precision::new(7.0, 0.0));
        assert!(a2 < a4 && a4 < a7);
    }

    #[test]
    fn mxint_cheaper_than_float_at_same_width() {
        // The shared exponent drops the per-element dynamic shifter.
        let mx = mac_area_luts(FormatKind::MxInt, Precision::new(7.0, 0.0));
        let fp = mac_area_luts(FormatKind::Fp32, Precision::new(32.0, 0.0));
        assert!(mx < fp / 10.0);
    }

    #[test]
    fn bl_has_no_multiplier_scaling() {
        // BL area grows linearly with exponent bits, not quadratically.
        let a4 = mac_area_luts(FormatKind::Bl, Precision::new(4.0, 0.0));
        let a8 = mac_area_luts(FormatKind::Bl, Precision::new(8.0, 0.0));
        assert!(a8 / a4 < 2.5);
    }

    #[test]
    fn gemm_op_scales_with_tile() {
        let p = Precision::new(5.0, 0.0);
        let a1 = op_area_luts(OpKind::Linear, FormatKind::MxInt, p, (4, 4));
        let a2 = op_area_luts(OpKind::Linear, FormatKind::MxInt, p, (8, 8));
        assert!(a2 > 3.0 * a1 && a2 < 4.5 * a1);
    }

    #[test]
    fn utilization_fraction() {
        let d = Device::u250();
        assert!((utilization(d.luts / 2.0, &d) - 0.5).abs() < 1e-12);
    }
}
