//! Throughput regression model for the dataflow pipeline (§4.2).
//!
//! Each operator processes one streaming tile per initiation interval; an
//! operator's cycle count per inference is its workload divided by its
//! tile parallelism. Since PR 5 the model is *bandwidth-aware*: tiles
//! cross the dataflow edges as bit-packed MX words over channels of
//! finite width ([`super::Device::channel_bits`]), so an operator also
//! cannot issue faster than it can stream — its streamed cycle count is
//! `max(compute cycles, tiles x transfer beats)` with
//! `beats = ceil(measured tile bits / channel width)`, the measured tile
//! bits coming from [`crate::packed::packed_bits_for`] (shared
//! exponents, guard bits and alignment padding included). The pipeline's
//! steady-state throughput is set by the slowest operator (paper §4.2:
//! "overall throughput is the minimum throughput among all hardware
//! operators"). The cycle-approximate simulator in [`crate::sim`]
//! applies the identical beat rule event-by-event and cross-validates
//! this closed form.

use super::Device;
use crate::ir::{Graph, OpKind};
use crate::packed::packed_bits_for;

/// Work (multiply-accumulates, or element ops) one inference pushes
/// through an operator, derived from its result tensor and inputs.
pub fn op_work(g: &Graph, op: &crate::ir::Operation) -> f64 {
    let out_elems: usize = op.results.iter().map(|&r| g.value(r).ty.elements()).sum();
    match op.kind {
        OpKind::Linear => {
            // out [.., M, N] with weight [K, N]: MACs = M*N*K
            let k = op.params.first().map(|&w| g.value(w).ty.shape[0]).unwrap_or(1);
            out_elems as f64 * k as f64
        }
        OpKind::Attention => {
            // QK^T + AV over seq x seq: ~2 * S * D per output row element
            let in_elems =
                op.args.first().map(|&a| g.value(a).ty.elements()).unwrap_or(out_elems) as f64;
            2.0 * in_elems * g.value(op.results[0]).ty.shape.last().copied().unwrap_or(1) as f64
        }
        OpKind::Embed => out_elems as f64,
        OpKind::LayerNorm | OpKind::Softmax | OpKind::Gelu => 3.0 * out_elems as f64,
        OpKind::Add | OpKind::MeanPool | OpKind::Transpose | OpKind::Reorder => out_elems as f64,
        OpKind::Input | OpKind::Output => 0.0,
    }
}

/// Cycles one inference spends *computing* in `op` at tile parallelism
/// `tile` — the channel-free half of the model; see
/// [`op_cycles_streamed`] for the bandwidth-aware count.
pub fn op_cycles(g: &Graph, op: &crate::ir::Operation, tile: (usize, usize)) -> f64 {
    let lanes = (tile.0 * tile.1).max(1) as f64;
    let w = op_work(g, op);
    if w == 0.0 {
        0.0
    } else {
        (w / lanes).ceil()
    }
}

/// Output tiles `op` emits per inference at tile shape `tile` — the
/// tile granularity shared by this closed form and the simulator's
/// graph lowering ([`crate::sim::nodes_from_graph`]).
pub fn op_tiles_per_inference(g: &Graph, op: &crate::ir::Operation, tile: (usize, usize)) -> u64 {
    let out_elems: usize = op.results.iter().map(|&r| g.value(r).ty.elements()).sum();
    let tile_elems = (tile.0 * tile.1).max(1);
    out_elems.max(1).div_ceil(tile_elems) as u64
}

/// Measured packed payload (bits) of one output tile of `op`: the bits
/// that actually cross the dataflow edge per firing, priced by
/// [`packed_bits_for`] over the tile shape in the result tensor's
/// format/precision — shared exponent bytes, guard bits and
/// word-alignment padding included. 0 for zero-result interface ops.
pub fn op_tile_bits(g: &Graph, op: &crate::ir::Operation, tile: (usize, usize)) -> u64 {
    match op.results.first() {
        Some(&r) => {
            let ty = &g.value(r).ty;
            packed_bits_for(ty.format, ty.precision, &[tile.0, tile.1])
        }
        None => 0,
    }
}

/// Beats one output tile of `op` needs to cross a `channel_bits`-wide
/// handshake channel (0 = unbounded: one beat, the
/// `sim::SimConfig::UNBOUNDED` sentinel).
pub fn op_transfer_beats(
    g: &Graph,
    op: &crate::ir::Operation,
    tile: (usize, usize),
    channel_bits: u64,
) -> f64 {
    if channel_bits == 0 {
        return 1.0;
    }
    op_tile_bits(g, op, tile).div_ceil(channel_bits).max(1) as f64
}

/// Bandwidth-aware cycles one inference spends in `op`: the operator can
/// neither compute faster than its MAC array nor issue faster than its
/// output channel drains, so the per-inference count is
/// `max(compute cycles, tiles x beats)`. Degrades exactly to
/// [`op_cycles`] whenever the channel keeps up (beats never exceed the
/// per-tile II), which is how the legacy model is recovered at
/// `channel_bits == 0` (unbounded).
pub fn op_cycles_streamed(
    g: &Graph,
    op: &crate::ir::Operation,
    tile: (usize, usize),
    channel_bits: u64,
) -> f64 {
    let compute = op_cycles(g, op, tile);
    if compute == 0.0 {
        return 0.0;
    }
    let tiles = op_tiles_per_inference(g, op, tile) as f64;
    compute.max(tiles * op_transfer_beats(g, op, tile, channel_bits))
}

/// Steady-state pipeline throughput in inferences/second: the slowest
/// operator's streamed cycle count bounds the initiation interval
/// (Fig. 1f) — since PR 5 an operator behind an under-provisioned
/// channel is slowed to its transfer rate, making the search objective
/// bandwidth-sensitive.
pub fn pipeline_throughput(g: &Graph, device: &Device) -> f64 {
    let max_cycles = g
        .ops
        .iter()
        .map(|op| {
            let tile = op.results.first().map(|&r| g.value(r).attrs.tile).unwrap_or((1, 1));
            op_cycles_streamed(g, op, tile, device.channel_bits)
        })
        .fold(0.0f64, f64::max);
    if max_cycles == 0.0 {
        0.0
    } else {
        device.clock_hz / max_cycles
    }
}

/// End-to-end latency of one inference: sum of per-op fill latencies
/// (non-dataflow lower bound in Fig. 1e is this sum; the dataflow design
/// overlaps inferences so throughput >> 1/latency). Streamed: a
/// transfer-bound stage fills at its channel rate.
pub fn pipeline_latency_cycles(g: &Graph, device: &Device) -> f64 {
    g.ops
        .iter()
        .map(|op| {
            let tile = op.results.first().map(|&r| g.value(r).attrs.tile).unwrap_or((1, 1));
            op_cycles_streamed(g, op, tile, device.channel_bits)
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::{FormatKind, Precision};
    use crate::ir::{Graph, OpKind, TensorType};

    fn linear_graph(tile: (usize, usize)) -> Graph {
        let mut g = Graph::new("t");
        let x = g.add_input("x", TensorType::fp32(vec![32, 64]));
        let w = g.new_value(
            "w",
            TensorType { shape: vec![64, 64], format: FormatKind::MxInt, precision: Precision::new(5.0, 0.0) },
            None,
        );
        let y = g.add_op(OpKind::Linear, vec![x], vec![w], "y", TensorType::fp32(vec![32, 64]), None);
        g.value_mut(y).attrs.tile = tile;
        g.outputs.push(y);
        g
    }

    #[test]
    fn linear_work_is_mnk() {
        let g = linear_graph((1, 1));
        let op = g.ops.iter().find(|o| o.kind == OpKind::Linear).unwrap();
        assert_eq!(op_work(&g, op), (32 * 64 * 64) as f64);
    }

    #[test]
    fn more_lanes_fewer_cycles() {
        let g1 = linear_graph((1, 1));
        let g2 = linear_graph((8, 8));
        let d = Device::u250();
        assert!(pipeline_throughput(&g2, &d) > 50.0 * pipeline_throughput(&g1, &d));
    }

    #[test]
    fn throughput_bounded_by_slowest_op() {
        // At the device's 512-bit channels a (2,2) fp32 tile (128 bits)
        // streams in one beat per 64 compute cycles: the closed form
        // must be exactly the compute bound.
        let g = linear_graph((2, 2));
        let d = Device::u250();
        let cycles = (32.0 * 64.0 * 64.0 / 4.0f64).ceil();
        assert!((pipeline_throughput(&g, &d) - d.clock_hz / cycles).abs() < 1e-6);
    }

    #[test]
    fn latency_sums_ops() {
        let g = linear_graph((1, 1));
        assert!(pipeline_latency_cycles(&g, &Device::u250()) >= 32.0 * 64.0 * 64.0);
    }

    #[test]
    fn tile_bits_are_measured_packed_storage() {
        let g = linear_graph((2, 2));
        let op = g.ops.iter().find(|o| o.kind == OpKind::Linear).unwrap();
        // result is fp32: 4 elements * 32 bits, word-aligned
        assert_eq!(op_tile_bits(&g, op, (2, 2)), 128);
        // and beats round up against the channel width
        assert_eq!(op_transfer_beats(&g, op, (2, 2), 512), 1.0);
        assert_eq!(op_transfer_beats(&g, op, (2, 2), 48), 3.0);
        assert_eq!(op_transfer_beats(&g, op, (2, 2), 0), 1.0, "unbounded = 1 beat");
    }

    #[test]
    fn narrow_channels_bound_the_closed_form() {
        // 8192-bit (16,16) fp32 tiles over starved channels: the linear
        // op becomes transfer-bound and throughput drops strictly.
        let g = linear_graph((16, 16));
        let wide = Device::u250();
        let mut narrow = Device::u250();
        narrow.channel_bits = 32;
        let t_wide = pipeline_throughput(&g, &wide);
        let t_narrow = pipeline_throughput(&g, &narrow);
        assert!(t_narrow < t_wide, "narrow {t_narrow} vs wide {t_wide}");
        // the transfer-bound count is tiles * beats exactly
        let op = g.ops.iter().find(|o| o.kind == OpKind::Linear).unwrap();
        let tiles = op_tiles_per_inference(&g, op, (16, 16)) as f64;
        let beats = op_transfer_beats(&g, op, (16, 16), 32);
        assert_eq!(op_cycles_streamed(&g, op, (16, 16), 32), tiles * beats);
    }

    #[test]
    fn streamed_cycles_degrade_to_compute_cycles() {
        let g = linear_graph((2, 2));
        let op = g.ops.iter().find(|o| o.kind == OpKind::Linear).unwrap();
        let compute = op_cycles(&g, op, (2, 2));
        assert_eq!(op_cycles_streamed(&g, op, (2, 2), 0), compute);
        assert_eq!(op_cycles_streamed(&g, op, (2, 2), 512), compute);
    }

    #[test]
    fn narrower_formats_need_fewer_beats() {
        // The whole point of MX formats on a dataflow fabric: MXInt4
        // tiles cross the same channel in strictly fewer beats than
        // 8-bit fixed point.
        let mk = |fmt, p| {
            let mut g = Graph::new("t");
            let x = g.add_input("x", TensorType::fp32(vec![32, 64]));
            let y = g.add_op(
                OpKind::Gelu,
                vec![x],
                vec![],
                "y",
                TensorType { shape: vec![32, 64], format: fmt, precision: p },
                None,
            );
            g.value_mut(y).attrs.tile = (16, 2);
            g.outputs.push(y);
            g
        };
        let g4 = mk(FormatKind::MxInt, Precision::new(3.0, 0.0)); // 4-bit elems + shared exp
        let g8 = mk(FormatKind::Int, Precision::new(8.0, 4.0));
        let op4 = g4.ops.iter().find(|o| o.kind == OpKind::Gelu).unwrap();
        let op8 = g8.ops.iter().find(|o| o.kind == OpKind::Gelu).unwrap();
        let b4 = op_transfer_beats(&g4, op4, (16, 2), 64);
        let b8 = op_transfer_beats(&g8, op8, (16, 2), 64);
        assert!(b4 < b8, "mxint4 {b4} beats vs fixed8 {b8} beats");
    }
}
