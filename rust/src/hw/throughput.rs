//! Throughput regression model for the dataflow pipeline (§4.2).
//!
//! Each operator processes one streaming tile per initiation interval; an
//! operator's cycle count per inference is its workload divided by its
//! tile parallelism. The pipeline's steady-state throughput is set by the
//! slowest operator (paper §4.2: "overall throughput is the minimum
//! throughput among all hardware operators"). The cycle-approximate
//! simulator in [`crate::sim`] cross-validates this closed form.

use super::Device;
use crate::ir::{Graph, OpKind};

/// Work (multiply-accumulates, or element ops) one inference pushes
/// through an operator, derived from its result tensor and inputs.
pub fn op_work(g: &Graph, op: &crate::ir::Operation) -> f64 {
    let out_elems: usize = op.results.iter().map(|&r| g.value(r).ty.elements()).sum();
    match op.kind {
        OpKind::Linear => {
            // out [.., M, N] with weight [K, N]: MACs = M*N*K
            let k = op.params.first().map(|&w| g.value(w).ty.shape[0]).unwrap_or(1);
            out_elems as f64 * k as f64
        }
        OpKind::Attention => {
            // QK^T + AV over seq x seq: ~2 * S * D per output row element
            let in_elems =
                op.args.first().map(|&a| g.value(a).ty.elements()).unwrap_or(out_elems) as f64;
            2.0 * in_elems * g.value(op.results[0]).ty.shape.last().copied().unwrap_or(1) as f64
        }
        OpKind::Embed => out_elems as f64,
        OpKind::LayerNorm | OpKind::Softmax | OpKind::Gelu => 3.0 * out_elems as f64,
        OpKind::Add | OpKind::MeanPool | OpKind::Transpose | OpKind::Reorder => out_elems as f64,
        OpKind::Input | OpKind::Output => 0.0,
    }
}

/// Cycles one inference spends in `op` at tile parallelism `tile`.
pub fn op_cycles(g: &Graph, op: &crate::ir::Operation, tile: (usize, usize)) -> f64 {
    let lanes = (tile.0 * tile.1).max(1) as f64;
    let w = op_work(g, op);
    if w == 0.0 {
        0.0
    } else {
        (w / lanes).ceil()
    }
}

/// Steady-state pipeline throughput in inferences/second: the slowest
/// operator's cycle count bounds the initiation interval (Fig. 1f).
pub fn pipeline_throughput(g: &Graph, device: &Device) -> f64 {
    let max_cycles = g
        .ops
        .iter()
        .map(|op| {
            let tile = op.results.first().map(|&r| g.value(r).attrs.tile).unwrap_or((1, 1));
            op_cycles(g, op, tile)
        })
        .fold(0.0f64, f64::max);
    if max_cycles == 0.0 {
        0.0
    } else {
        device.clock_hz / max_cycles
    }
}

/// End-to-end latency of one inference: sum of per-op fill latencies
/// (non-dataflow lower bound in Fig. 1e is this sum; the dataflow design
/// overlaps inferences so throughput >> 1/latency).
pub fn pipeline_latency_cycles(g: &Graph) -> f64 {
    g.ops
        .iter()
        .map(|op| {
            let tile = op.results.first().map(|&r| g.value(r).attrs.tile).unwrap_or((1, 1));
            op_cycles(g, op, tile)
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::{FormatKind, Precision};
    use crate::ir::{Graph, OpKind, TensorType};

    fn linear_graph(tile: (usize, usize)) -> Graph {
        let mut g = Graph::new("t");
        let x = g.add_input("x", TensorType::fp32(vec![32, 64]));
        let w = g.new_value(
            "w",
            TensorType { shape: vec![64, 64], format: FormatKind::MxInt, precision: Precision::new(5.0, 0.0) },
            None,
        );
        let y = g.add_op(OpKind::Linear, vec![x], vec![w], "y", TensorType::fp32(vec![32, 64]), None);
        g.value_mut(y).attrs.tile = tile;
        g.outputs.push(y);
        g
    }

    #[test]
    fn linear_work_is_mnk() {
        let g = linear_graph((1, 1));
        let op = g.ops.iter().find(|o| o.kind == OpKind::Linear).unwrap();
        assert_eq!(op_work(&g, op), (32 * 64 * 64) as f64);
    }

    #[test]
    fn more_lanes_fewer_cycles() {
        let g1 = linear_graph((1, 1));
        let g2 = linear_graph((8, 8));
        let d = Device::u250();
        assert!(pipeline_throughput(&g2, &d) > 50.0 * pipeline_throughput(&g1, &d));
    }

    #[test]
    fn throughput_bounded_by_slowest_op() {
        let g = linear_graph((2, 2));
        let d = Device::u250();
        let cycles = (32.0 * 64.0 * 64.0 / 4.0f64).ceil();
        assert!((pipeline_throughput(&g, &d) - d.clock_hz / cycles).abs() < 1e-6);
    }

    #[test]
    fn latency_sums_ops() {
        let g = linear_graph((1, 1));
        assert!(pipeline_latency_cycles(&g) >= 32.0 * 64.0 * 64.0);
    }
}
