//! Energy model for Fig. 8 (energy efficiency of MXInt designs).
//!
//! Dynamic energy per MAC is proportional to its switched capacitance,
//! which tracks its area (standard CMOS proxy); data movement pays per
//! bit, with off-chip DRAM ~50x more expensive than on-chip SRAM. Energy
//! efficiency is inferences per joule.

use super::{area, Device};
use crate::formats::{FormatKind, Precision};
use crate::ir::Graph;

/// pJ per LUT-equivalent of active datapath per cycle (calibration
/// constant — only *relative* energies matter for Fig. 8's shape).
const PJ_PER_LUT: f64 = 0.08;
/// pJ per bit moved on-chip / off-chip.
const PJ_PER_BIT_ONCHIP: f64 = 0.05;
const PJ_PER_BIT_OFFCHIP: f64 = 2.5;

/// Dynamic energy (joules) of one inference through the design.
pub fn inference_energy_j(g: &Graph, fmt: FormatKind, offchip_param_bits: f64) -> f64 {
    let mut pj = 0.0;
    for op in &g.ops {
        let (p, tile) = op
            .results
            .first()
            .map(|&r| {
                let v = g.value(r);
                (v.ty.precision, v.attrs.tile)
            })
            .unwrap_or((Precision::new(8.0, 0.0), (1, 1)));
        let _ = tile; // energy = (work/lanes) * (lanes * mac_area): lanes cancel
        let work = super::throughput::op_work(g, op);
        let unit = if op.kind.is_gemm() {
            area::mac_area_luts(fmt, p)
        } else {
            60.0 // fixed-function per-element datapath
        };
        pj += work * unit * PJ_PER_LUT;
        // stream the op's output tensor on-chip
        let out_bits: f64 = op.results.iter().map(|&r| g.value(r).ty.bits()).sum();
        pj += out_bits * PJ_PER_BIT_ONCHIP;
    }
    pj += offchip_param_bits * PJ_PER_BIT_OFFCHIP;
    pj * 1e-12
}

/// Inferences per joule, including static power amortized at the achieved
/// throughput.
pub fn energy_efficiency(g: &Graph, fmt: FormatKind, device: &Device, offchip_param_bits: f64) -> f64 {
    let thr = super::throughput::pipeline_throughput(g, device);
    if thr <= 0.0 {
        return 0.0;
    }
    let dyn_j = inference_energy_j(g, fmt, offchip_param_bits);
    let static_j = device.static_watts / thr;
    1.0 / (dyn_j + static_j)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{OpKind, TensorType};

    fn graph_with_precision(bits: f32) -> Graph {
        let mut g = Graph::new("e");
        let x = g.add_input("x", TensorType::fp32(vec![32, 64]));
        let w = g.new_value(
            "w",
            TensorType {
                shape: vec![64, 64],
                format: FormatKind::MxInt,
                precision: Precision::new(bits, 0.0),
            },
            None,
        );
        let y = g.add_op(
            OpKind::Linear,
            vec![x],
            vec![w],
            "y",
            TensorType {
                shape: vec![32, 64],
                format: FormatKind::MxInt,
                precision: Precision::new(bits, 0.0),
            },
            None,
        );
        g.value_mut(y).attrs.tile = (8, 8);
        g.outputs.push(y);
        g
    }

    #[test]
    fn lower_precision_uses_less_energy() {
        let e4 = inference_energy_j(&graph_with_precision(3.0), FormatKind::MxInt, 0.0);
        let e8 = inference_energy_j(&graph_with_precision(7.0), FormatKind::MxInt, 0.0);
        assert!(e4 < e8, "{e4} {e8}");
    }

    #[test]
    fn offchip_traffic_costs() {
        let g = graph_with_precision(5.0);
        let a = inference_energy_j(&g, FormatKind::MxInt, 0.0);
        let b = inference_energy_j(&g, FormatKind::MxInt, 1e6);
        assert!(b > a);
    }

    #[test]
    fn efficiency_positive_and_finite() {
        let g = graph_with_precision(5.0);
        let e = energy_efficiency(&g, FormatKind::MxInt, &Device::u250(), 1e5);
        assert!(e.is_finite() && e > 0.0);
    }
}
