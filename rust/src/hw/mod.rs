//! Hardware operator library and cost models — the substitute for the
//! paper's Alveo U250 + Vivado post-P&R measurements.
//!
//! The paper itself does not call Vivado inside the search loop: it fits a
//! one-off *regression model* over its parameterized operator templates
//! and evaluates designs at the source level (§3.2, Table 4). We do the
//! same, with the structural area models of [`area`] calibrated so the
//! paper's published Table 1 anchors hold exactly at the 8-bit configs:
//!
//! | format | arithmetic density (vs FP32) | memory density |
//! |--------|------------------------------|----------------|
//! | int8   | 7.7x                         | 4x             |
//! | FP8    | 17.4x                        | 4x             |
//! | MXInt8 | 14.4x                        | 3.8x           |
//! | BMF8   | 14.4x                        | 3.8x           |
//! | BL8    | 16.1x                        | 3.8x           |
//!
//! Memory density needs no calibration: it follows from Eq. (1).
//!
//! Submodule map (each feeds one half of the `evaluate` pass's hardware
//! score, combined by `passes::evaluate::Objective`):
//!
//!  * [`area`] — LUT-equivalent structural area per operator template,
//!    calibrated to the Table 1 anchors above; sums to the `A` of Eq. (4).
//!  * [`memory`] — Eq. (1) storage density per format/precision, and
//!    the on-chip/off-chip split the parallelize pass budgets against.
//!  * [`throughput`] — closed-form initiation-interval/latency model per
//!    operator (the `θ` of Eq. 4), cross-validated against [`crate::sim`].
//!  * [`energy`] — per-op dynamic energy for the Fig. 8 comparison.
//!
//! Everything here is pure arithmetic over the IR: no PJRT, no
//! simulator, no I/O — which is what lets the search pass score
//! thousands of candidate designs per second, and what lets a warm
//! [`crate::search::CacheStore`] rebuild a winning design point without
//! re-running any evaluation.

pub mod area;
pub mod energy;
pub mod memory;
pub mod throughput;

use crate::formats::{FormatKind, Precision};

/// Default on-fabric handshake channel width in bits (one AXI-stream
/// beat): what [`Device::u250`] provisions per dataflow edge, what the
/// emitted unpacker templates deserialize, and the width the
/// bandwidth-aware simulator ([`crate::sim`]) models by default.
pub const DEFAULT_CHANNEL_BITS: u64 = 512;

/// Target device model (Alveo U250-like budget).
#[derive(Debug, Clone)]
pub struct Device {
    pub name: &'static str,
    /// LUT-equivalent logic budget.
    pub luts: f64,
    /// On-chip memory budget in bits (URAM+BRAM).
    pub onchip_bits: f64,
    /// Clock in Hz.
    pub clock_hz: f64,
    /// Off-chip bandwidth in bits/s.
    pub offchip_bits_per_s: f64,
    /// Static power in W.
    pub static_watts: f64,
    /// On-fabric handshake channel width in bits: one packed tile
    /// streams across a dataflow edge in `ceil(tile_bits / channel_bits)`
    /// beats (the §4.2 parallelism knob the beat model prices).
    /// 0 = unbounded, the same sentinel `sim::SimConfig::UNBOUNDED` uses.
    pub channel_bits: u64,
}

impl Device {
    pub fn u250() -> Self {
        Device {
            name: "alveo-u250-sim",
            luts: 1_728_000.0,
            onchip_bits: 2.8e9 * 8.0 / 16.0, // ~54 MB URAM+BRAM -> bits/16 conservatively
            clock_hz: 250e6,
            offchip_bits_per_s: 77e9 * 8.0,
            static_watts: 20.0,
            channel_bits: DEFAULT_CHANNEL_BITS,
        }
    }

    /// A smaller budget used by fast tests.
    pub fn small() -> Self {
        Device { name: "small-sim", luts: 200_000.0, ..Self::u250() }
    }
}

/// Arithmetic density vs FP32 for a GEMM operator at a given precision —
/// Table 1's "Arithmetic Density" column.
pub fn arithmetic_density(fmt: FormatKind, p: Precision) -> f64 {
    area::mac_area_luts(FormatKind::Fp32, Precision::new(32.0, 0.0))
        / area::mac_area_luts(fmt, p)
}

/// Memory density vs FP32 — Table 1's "Memory Density" column (Eq. 1).
pub fn memory_density(fmt: FormatKind, p: Precision) -> f64 {
    32.0 / p.average_bitwidth(fmt)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p8(fmt: FormatKind) -> Precision {
        match fmt {
            FormatKind::Int => Precision::new(8.0, 4.0),
            // 8-bit elements: MXInt m=7 (+sign), BMF m=5 (+2e +sign), BL e=7 (+sign)
            FormatKind::MxInt => Precision::new(7.0, 0.0),
            FormatKind::Bmf => Precision::new(5.0, 0.0),
            FormatKind::Bl => Precision::new(7.0, 0.0),
            _ => Precision::new(8.0, 0.0),
        }
    }

    #[test]
    fn table1_arithmetic_density_anchors() {
        let cases = [
            (FormatKind::Int, 7.7),
            (FormatKind::Fp8, 17.4),
            (FormatKind::MxInt, 14.4),
            (FormatKind::Bmf, 14.4),
            (FormatKind::Bl, 16.1),
        ];
        for (fmt, want) in cases {
            let got = arithmetic_density(fmt, p8(fmt));
            assert!(
                (got - want).abs() / want < 0.01,
                "{}: got {got}, want {want}",
                fmt.name()
            );
        }
    }

    #[test]
    fn table1_memory_density_anchors() {
        assert!((memory_density(FormatKind::Int, p8(FormatKind::Int)) - 4.0).abs() < 1e-9);
        assert!((memory_density(FormatKind::Fp8, p8(FormatKind::Fp8)) - 4.0).abs() < 1e-9);
        let mx = memory_density(FormatKind::MxInt, p8(FormatKind::MxInt));
        assert!((mx - 3.88).abs() < 0.01, "{mx}"); // paper rounds to 3.8x
    }

    #[test]
    fn lower_precision_is_denser() {
        let d4 = arithmetic_density(FormatKind::MxInt, Precision::new(3.0, 0.0));
        let d8 = arithmetic_density(FormatKind::MxInt, Precision::new(7.0, 0.0));
        assert!(d4 > d8);
    }

    #[test]
    fn fp32_density_is_one() {
        assert!((arithmetic_density(FormatKind::Fp32, Precision::new(32.0, 0.0)) - 1.0).abs() < 1e-12);
        assert!((memory_density(FormatKind::Fp32, Precision::new(32.0, 0.0)) - 1.0).abs() < 1e-12);
    }
}
