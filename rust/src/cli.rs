//! Typed command-line surface for the `mase` driver — the ONE place
//! where raw `--flag` strings become typed configuration.
//!
//! Before this module, every subcommand arm in `main.rs` re-parsed its
//! own `--fmt`/`--bits`/`--backend`/... copies (seven near-identical
//! blocks, three duplicated per-family default-bits tables, and as many
//! error phrasings). [`CommonArgs::parse`] replaces them:
//!
//!  * **one parser** — every shared flag is decoded here, strictly
//!    (a malformed `--trials x7` is an error, never a silent default);
//!  * **one error style** — `--flag: problem (accepted values)`;
//!  * **exhaustive match** — subcommands are the [`Subcommand`] enum, so
//!    adding one without wiring it into the driver is a compile error;
//!  * **one format type** — `--fmt/--bits/--frac` become the same
//!    [`FormatSpec`] that `.mxa` artifact headers
//!    ([`crate::packed::artifact`]) carry, with the per-family default
//!    bits defined once in [`FormatSpec::default_bits`];
//!  * **validated flags** — each subcommand declares the flags it
//!    accepts; a typo'd `--trails` is reported instead of ignored.
//!
//! Builders ([`CommonArgs::flow_config`], [`CommonArgs::sweep_config`])
//! assemble the coordinator configs, so `--weights model.mxa` reaches
//! [`FlowConfig::weights_artifact`] / [`SweepConfig::weights_artifact`]
//! from every flow-shaped subcommand through a single code path.

use crate::coordinator::{FlowConfig, Session, SweepConfig};
use crate::data::Task;
use crate::formats::{FormatKind, FormatSpec};
use crate::runtime::BackendKind;
use crate::search::Algorithm;
use crate::util::cli::Args;
use anyhow::{anyhow, Result};
use std::path::PathBuf;

/// Every `mase` subcommand. The driver matches this exhaustively:
/// adding a variant without handling it everywhere is a compile error.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Subcommand {
    Help,
    Pretrain,
    Profile,
    Search,
    E2e,
    Emit,
    Sweep,
    Ir,
    Check,
    Formats,
    Generate,
    Serve,
    Trace,
    Pack,
}

impl Subcommand {
    pub const ALL: [Subcommand; 14] = [
        Subcommand::Help,
        Subcommand::Pretrain,
        Subcommand::Profile,
        Subcommand::Search,
        Subcommand::E2e,
        Subcommand::Emit,
        Subcommand::Sweep,
        Subcommand::Ir,
        Subcommand::Check,
        Subcommand::Formats,
        Subcommand::Generate,
        Subcommand::Serve,
        Subcommand::Trace,
        Subcommand::Pack,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Subcommand::Help => "help",
            Subcommand::Pretrain => "pretrain",
            Subcommand::Profile => "profile",
            Subcommand::Search => "search",
            Subcommand::E2e => "e2e",
            Subcommand::Emit => "emit",
            Subcommand::Sweep => "sweep",
            Subcommand::Ir => "ir",
            Subcommand::Check => "check",
            Subcommand::Formats => "formats",
            Subcommand::Generate => "generate",
            Subcommand::Serve => "serve",
            Subcommand::Trace => "trace",
            Subcommand::Pack => "pack",
        }
    }

    pub fn from_name(s: &str) -> Option<Subcommand> {
        Subcommand::ALL.into_iter().find(|c| c.name() == s)
    }

    /// The flags this subcommand understands (besides `--artifacts`,
    /// accepted everywhere). Unknown flags are rejected at parse time —
    /// a silently ignored `--trails 64` has burned enough CI hours.
    fn allowed_flags(self) -> &'static [&'static str] {
        const FLOW: &[&str] = &[
            "model", "task", "fmt", "algorithm", "trials", "eval-batches", "qat-steps",
            "sw-only", "seed", "out", "pretrain-steps", "threads", "batch", "cache",
            "tpe-mean-lie", "backend", "trace", "trace-format", "weights",
        ];
        match self {
            Subcommand::Help => &[],
            Subcommand::Pretrain => &["backend", "all", "model", "task", "steps"],
            Subcommand::Profile => &["backend", "model", "task"],
            Subcommand::Search | Subcommand::E2e | Subcommand::Emit => FLOW,
            Subcommand::Sweep => &[
                "backend", "models", "tasks", "fmts", "algorithm", "trials", "seed", "batch",
                "threads", "eval-batches", "pretrain-steps", "qat-steps", "qat-lr", "sw-only",
                "tpe-mean-lie", "cache", "trace", "trace-format", "weights",
            ],
            Subcommand::Ir => &["backend", "model"],
            Subcommand::Check => &[
                "sv", "model", "fmt", "bits", "chan", "layers", "d-model", "heads", "vocab",
                "seq",
            ],
            Subcommand::Formats => &["backend", "model", "eval-batches"],
            Subcommand::Generate => &[
                "backend", "model", "fmt", "bits", "tokens", "prompt-len", "seqs", "threads",
                "trace", "trace-format", "weights",
            ],
            Subcommand::Serve => &[
                "backend", "model", "fmt", "bits", "port", "lanes", "queue-cap",
                "queue-timeout-ms", "max-tokens", "http-workers", "weights",
            ],
            Subcommand::Trace => &[
                "backend", "model", "fmt", "bits", "chan", "inferences", "fifo", "out",
                "trace-format", "run",
            ],
            Subcommand::Pack => &[
                "model", "task", "fmt", "bits", "frac", "out", "layers", "d-model", "heads",
                "vocab", "seq",
            ],
        }
    }
}

/// Strictly-typed `--key N` (unsigned integer). Absent -> `default`;
/// present-but-malformed -> error (never a silent fallback).
pub fn flag_usize(args: &Args, key: &str, default: usize) -> Result<usize> {
    match args.get(key) {
        None => Ok(default),
        Some(v) => v
            .parse()
            .map_err(|_| anyhow!("--{key}: expected an unsigned integer, got '{v}'")),
    }
}

/// Strictly-typed `--key X` (finite number).
pub fn flag_f32(args: &Args, key: &str, default: f32) -> Result<f32> {
    match args.get(key) {
        None => Ok(default),
        Some(v) => match v.parse::<f32>() {
            Ok(x) if x.is_finite() => Ok(x),
            _ => Err(anyhow!("--{key}: expected a finite number, got '{v}'")),
        },
    }
}

/// Every flag shared across subcommands, decoded once, strictly.
/// Subcommand-unique knobs (`--port`, `--chan`, ...) stay in the driver
/// but go through the same typed [`flag_usize`]/[`flag_f32`] helpers.
#[derive(Debug, Clone)]
pub struct CommonArgs {
    pub sub: Subcommand,
    /// `--artifacts DIR` (default: `Session::default_dir`).
    pub artifacts: PathBuf,
    pub backend: BackendKind,
    pub model: Option<String>,
    pub task: Task,
    /// `--fmt` (default mxint). Combine with `bits`/`frac` via
    /// [`CommonArgs::spec`].
    pub fmt: FormatKind,
    /// Raw `--bits`, if given; default resolution is per-command:
    /// [`CommonArgs::spec`] uses the family default, [`bits_or`] a
    /// caller-chosen one (check/trace historically default to 5).
    ///
    /// [`bits_or`]: CommonArgs::bits_or
    pub bits: Option<f32>,
    pub frac: f32,
    pub algorithm: Algorithm,
    pub trials: Option<usize>,
    pub eval_batches: Option<usize>,
    pub qat_steps: usize,
    pub qat_lr: f32,
    pub hw_aware: bool,
    pub seed: u64,
    pub pretrain_steps: usize,
    pub threads: usize,
    pub batch: usize,
    pub cache: Option<PathBuf>,
    pub tpe_mean_lie: bool,
    /// `--trace` / `--trace FILE`: `None` = off, `Some("true")` = record
    /// + summarize, `Some(path)` = also export (see `trace_file`).
    pub trace: Option<String>,
    pub trace_format: Option<String>,
    /// `--weights model.mxa`: serve packed weight tensors from a content-
    /// addressed artifact (CPU backend; zero re-quantize, zero re-pack).
    pub weights: Option<PathBuf>,
    pub out: Option<String>,
    /// Sweep grid axes (populated for `sweep` only).
    pub models: Vec<String>,
    pub tasks: Vec<Task>,
    pub fmts: Vec<FormatKind>,
}

impl CommonArgs {
    pub fn parse(args: &Args) -> Result<CommonArgs> {
        let sub = match &args.subcommand {
            None => Subcommand::Help,
            Some(s) => Subcommand::from_name(s).ok_or_else(|| {
                anyhow!(
                    "unknown subcommand '{s}' (expected one of: {})",
                    Subcommand::ALL.map(Subcommand::name).join("|")
                )
            })?,
        };
        // `mase trace --run X` forwards its whole flag set to X, which
        // re-parses (and re-validates) under X's own allowlist.
        let delegating = sub == Subcommand::Trace && args.get("run").is_some();
        if sub != Subcommand::Help && !delegating {
            let allowed = sub.allowed_flags();
            for key in args.flags.keys() {
                if key != "artifacts" && !allowed.contains(&key.as_str()) {
                    return Err(anyhow!(
                        "--{key}: unknown flag for `mase {}` (accepted: --artifacts{})",
                        sub.name(),
                        allowed.iter().map(|f| format!(", --{f}")).collect::<String>()
                    ));
                }
            }
        }

        let backend_name = args.get_or("backend", "pjrt");
        let backend = BackendKind::from_name(&backend_name)
            .ok_or_else(|| anyhow!("--backend: unknown backend '{backend_name}' (pjrt|cpu)"))?;
        let task_name = args.get_or("task", "sst2");
        let task = Task::from_name(&task_name)
            .ok_or_else(|| anyhow!("--task: unknown task '{task_name}'"))?;
        let fmt_name = args.get_or("fmt", "mxint");
        let fmt = FormatKind::from_name(&fmt_name).ok_or_else(|| {
            anyhow!("--fmt: unknown format '{fmt_name}' (fp32|int|fp8|mxint|bmf|bl)")
        })?;
        let alg_name = args.get_or("algorithm", "tpe");
        let algorithm = Algorithm::from_name(&alg_name).ok_or_else(|| {
            anyhow!("--algorithm: unknown algorithm '{alg_name}' (tpe|random|qmc|nsga2)")
        })?;

        let bits = match args.get("bits") {
            None => None,
            Some(_) => Some(flag_f32(args, "bits", 0.0)?),
        };
        let (tasks, fmts) = if sub == Subcommand::Sweep {
            let tasks = match args.get_or("tasks", "all").as_str() {
                "all" => Task::ALL.to_vec(),
                csv => csv
                    .split(',')
                    .map(|t| Task::from_name(t).ok_or_else(|| anyhow!("--tasks: unknown task '{t}'")))
                    .collect::<Result<Vec<_>>>()?,
            };
            let fmts = args
                .get_or("fmts", "mxint,int")
                .split(',')
                .map(|f| {
                    FormatKind::from_name(f).ok_or_else(|| anyhow!("--fmts: unknown format '{f}'"))
                })
                .collect::<Result<Vec<_>>>()?;
            (tasks, fmts)
        } else {
            (Vec::new(), Vec::new())
        };

        Ok(CommonArgs {
            sub,
            artifacts: args
                .get("artifacts")
                .map(PathBuf::from)
                .unwrap_or_else(Session::default_dir),
            backend,
            model: args.get("model").map(str::to_string),
            task,
            fmt,
            bits,
            frac: flag_f32(args, "frac", 0.0)?,
            algorithm,
            trials: match args.get("trials") {
                None => None,
                Some(_) => Some(flag_usize(args, "trials", 0)?),
            },
            eval_batches: match args.get("eval-batches") {
                None => None,
                Some(_) => Some(flag_usize(args, "eval-batches", 0)?),
            },
            qat_steps: flag_usize(args, "qat-steps", 0)?,
            qat_lr: flag_f32(args, "qat-lr", 0.002)?,
            hw_aware: !args.has("sw-only"),
            seed: flag_usize(args, "seed", 0)? as u64,
            pretrain_steps: flag_usize(args, "pretrain-steps", 220)?,
            threads: flag_usize(args, "threads", 0)?,
            batch: flag_usize(args, "batch", 8)?,
            cache: args.get("cache").map(PathBuf::from),
            tpe_mean_lie: args.has("tpe-mean-lie"),
            trace: args.get("trace").map(str::to_string),
            trace_format: args.get("trace-format").map(str::to_string),
            weights: args.get("weights").map(PathBuf::from),
            out: args.get("out").map(str::to_string),
            models: args
                .get_or("models", "opt-125m-sim,opt-350m-sim,opt-1.3b-sim")
                .split(',')
                .map(str::to_string)
                .collect(),
            tasks,
            fmts,
        })
    }

    /// `--fmt/--bits/--frac` as one [`FormatSpec`], family-default bits
    /// when `--bits` is absent — the same spec `.mxa` headers carry.
    pub fn spec(&self) -> FormatSpec {
        FormatSpec::new(self.fmt, self.bits_or(FormatSpec::default_bits(self.fmt)), self.frac)
    }

    /// `--bits` with a caller-chosen default (check/trace default to 5).
    pub fn bits_or(&self, default: f32) -> f32 {
        self.bits.unwrap_or(default)
    }

    pub fn require_model(&self) -> Result<&str> {
        self.model.as_deref().ok_or_else(|| anyhow!("--model required"))
    }

    pub fn model_or(&self, default: &str) -> String {
        self.model.clone().unwrap_or_else(|| default.to_string())
    }

    pub fn trace_enabled(&self) -> bool {
        self.trace.is_some()
    }

    /// The export path from `--trace FILE` (`None` for bare `--trace`).
    pub fn trace_file(&self) -> Option<&str> {
        self.trace.as_deref().filter(|p| *p != "true")
    }

    /// Assemble the flow configuration for `search`/`e2e`/`emit`.
    pub fn flow_config(&self, model: &str, emit_dir: Option<PathBuf>) -> FlowConfig {
        FlowConfig {
            model: model.to_string(),
            task: self.task,
            fmt: self.fmt,
            algorithm: self.algorithm,
            trials: self.trials.unwrap_or(32),
            eval_batches: self.eval_batches.unwrap_or(4),
            qat_steps: self.qat_steps,
            hw_aware: self.hw_aware,
            seed: self.seed,
            emit_dir,
            pretrain_steps: self.pretrain_steps,
            threads: self.threads,
            batch: self.batch.max(1),
            cache_path: self.cache.clone(),
            tpe_mean_lie: self.tpe_mean_lie,
            backend: self.backend,
            trace: self.trace_enabled(),
            weights_artifact: self.weights.clone(),
        }
    }

    /// Assemble the sweep configuration (`sweep` defaults: 24 trials,
    /// 3 eval batches).
    pub fn sweep_config(&self) -> SweepConfig {
        SweepConfig {
            models: self.models.clone(),
            tasks: self.tasks.clone(),
            fmts: self.fmts.clone(),
            algorithm: self.algorithm,
            trials: self.trials.unwrap_or(24),
            seed: self.seed,
            batch: self.batch.max(1),
            threads: self.threads,
            eval_batches: self.eval_batches.unwrap_or(3),
            pretrain_steps: self.pretrain_steps,
            qat_steps: self.qat_steps,
            qat_lr: self.qat_lr,
            hw_aware: self.hw_aware,
            tpe_mean_lie: self.tpe_mean_lie,
            cache_path: self.cache.clone(),
            backend: self.backend,
            trace: self.trace_enabled(),
            weights_artifact: self.weights.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<CommonArgs> {
        CommonArgs::parse(&Args::parse(s.split_whitespace().map(String::from)))
    }

    #[test]
    fn every_subcommand_round_trips_by_name() {
        for sub in Subcommand::ALL {
            assert_eq!(Subcommand::from_name(sub.name()), Some(sub));
        }
        assert_eq!(Subcommand::from_name("frobnicate"), None);
    }

    #[test]
    fn flow_flags_parse_into_typed_config() {
        let c = parse(
            "e2e --model toy-sim --task qqp --fmt int --trials 4 --batch 2 \
             --eval-batches 1 --threads 1 --backend cpu --weights w.mxa",
        )
        .unwrap();
        assert_eq!(c.sub, Subcommand::E2e);
        assert_eq!(c.backend, BackendKind::Cpu);
        let cfg = c.flow_config(c.require_model().unwrap(), None);
        assert_eq!(cfg.model, "toy-sim");
        assert_eq!(cfg.task, Task::Qqp);
        assert_eq!(cfg.fmt, FormatKind::Int);
        assert_eq!((cfg.trials, cfg.batch, cfg.eval_batches, cfg.threads), (4, 2, 1, 1));
        assert_eq!(cfg.weights_artifact.as_deref(), Some(std::path::Path::new("w.mxa")));
        assert!(!cfg.trace);
    }

    #[test]
    fn spec_uses_family_default_bits() {
        let c = parse("pack --fmt bmf").unwrap();
        let s = c.spec();
        assert_eq!((s.kind, s.bits, s.frac), (FormatKind::Bmf, 5.0, 0.0));
        let c = parse("pack --fmt int --bits 6 --frac 2").unwrap();
        assert_eq!((c.spec().bits, c.spec().frac), (6.0, 2.0));
        // check/trace keep their historical default of 5 bits
        assert_eq!(parse("check --fmt mxint").unwrap().bits_or(5.0), 5.0);
    }

    #[test]
    fn malformed_and_unknown_flags_are_errors_not_defaults() {
        assert!(parse("e2e --model m --trials x7").unwrap_err().to_string().contains("--trials"));
        assert!(parse("e2e --model m --bits NaN").is_err());
        let e = parse("e2e --model m --trails 64").unwrap_err().to_string();
        assert!(e.contains("--trails") && e.contains("unknown flag"), "{e}");
        let e = parse("serve --trace").unwrap_err().to_string();
        assert!(e.contains("--trace"), "{e}");
        assert!(parse("frobnicate").unwrap_err().to_string().contains("unknown subcommand"));
    }

    #[test]
    fn trace_delegation_skips_local_flag_validation() {
        // `mase trace --run e2e --trials 4` carries e2e's flags; they are
        // validated after forwarding, not against trace's own allowlist.
        let c = parse("trace --run e2e --model toy-sim --trials 4").unwrap();
        assert_eq!(c.sub, Subcommand::Trace);
    }

    #[test]
    fn trace_file_distinguishes_bare_from_path() {
        let c = parse("e2e --model m --trace --threads 1").unwrap();
        assert!(c.trace_enabled() && c.trace_file().is_none());
        let c = parse("e2e --model m --trace out.jsonl").unwrap();
        assert_eq!(c.trace_file(), Some("out.jsonl"));
        assert!(!parse("e2e --model m").unwrap().trace_enabled());
    }

    #[test]
    fn sweep_axes_parse_with_sweep_defaults() {
        let c = parse("sweep --models a,b --tasks sst2,qqp --fmts mxint --backend cpu").unwrap();
        let cfg = c.sweep_config();
        assert_eq!(cfg.models, vec!["a", "b"]);
        assert_eq!(cfg.tasks, vec![Task::Sst2, Task::Qqp]);
        assert_eq!(cfg.fmts, vec![FormatKind::MxInt]);
        assert_eq!((cfg.trials, cfg.eval_batches), (24, 3));
        assert!(parse("sweep --fmts nope").is_err());
        assert!(parse("sweep --tasks nope").is_err());
    }
}
