//! The six GLUE-like synthetic classification tasks (paper §5 datasets).
//!
//! Token-id layout within the 512-token vocabulary:
//!   0          PAD / BOS
//!   1..10      separators and question markers
//!   10..40     "positive sentiment" content tokens
//!   40..70     "negative sentiment" content tokens
//!   70..100    key/query tokens for boolq
//!   100..512   background vocabulary (Zipf-ish)

use crate::util::rng::Rng;

pub const VOCAB: usize = 512;
const SEP: i32 = 1;
const Q0: i32 = 2;
const Q1: i32 = 3;
const POS0: i32 = 10;
const NEG0: i32 = 40;
const KEY0: i32 = 70;
const BG0: i32 = 100;

/// One labelled example.
#[derive(Debug, Clone)]
pub struct TaskSample {
    pub tokens: Vec<i32>,
    pub label: u8,
}

/// The paper's six downstream tasks (synthetic simulants).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Task {
    BoolQ,
    Mnli,
    Qnli,
    Qqp,
    Rte,
    Sst2,
}

impl Task {
    pub const ALL: [Task; 6] = [Task::BoolQ, Task::Mnli, Task::Qnli, Task::Qqp, Task::Rte, Task::Sst2];

    pub fn name(&self) -> &'static str {
        match self {
            Task::BoolQ => "boolq",
            Task::Mnli => "mnli",
            Task::Qnli => "qnli",
            Task::Qqp => "qqp",
            Task::Rte => "rte",
            Task::Sst2 => "sst2",
        }
    }

    pub fn from_name(s: &str) -> Option<Task> {
        Task::ALL.iter().copied().find(|t| t.name() == s)
    }

    pub fn n_classes(&self) -> usize {
        match self {
            Task::Mnli => 3,
            _ => 2,
        }
    }

    /// Deterministic sample `idx` of `split` (0=train, 1=eval).
    pub fn sample(&self, split: u64, idx: u64, seq: usize) -> TaskSample {
        // Hash (task, split, idx) into a seed: splits/streams independent.
        let tag = *self as u64;
        let seed = tag
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(split.wrapping_mul(0xD1B54A32D192ED03))
            .wrapping_add(idx.wrapping_mul(0x2545F4914F6CDD1D));
        let mut rng = Rng::new(seed);
        match self {
            Task::Sst2 => sst2(&mut rng, seq),
            Task::BoolQ => boolq(&mut rng, seq),
            Task::Qnli => qnli(&mut rng, seq),
            Task::Qqp => qqp(&mut rng, seq),
            Task::Rte => rte(&mut rng, seq),
            Task::Mnli => mnli(&mut rng, seq),
        }
    }
}

fn bg_token(rng: &mut Rng) -> i32 {
    // Zipf-ish background: low ids much more frequent.
    let u = rng.uniform();
    let n = (VOCAB - BG0 as usize) as f64;
    BG0 + (n * u * u) as i32
}

/// sst2-sim: sentiment by token counting. Inject `k_pos` positive and
/// `k_neg` negative content tokens into background text; label by majority.
fn sst2(rng: &mut Rng, seq: usize) -> TaskSample {
    let label = rng.below(2) as u8;
    // Majority margin of at least 2 so the task is cleanly separable.
    let minor = rng.below(seq / 8) as i32;
    let major = minor + 2 + rng.below(3) as i32;
    let (k_pos, k_neg) = if label == 1 { (major, minor) } else { (minor, major) };
    let mut tokens: Vec<i32> = (0..seq).map(|_| bg_token(rng)).collect();
    let mut slots: Vec<usize> = (0..seq).collect();
    rng.shuffle(&mut slots);
    let mut s = 0;
    for _ in 0..k_pos {
        tokens[slots[s]] = POS0 + rng.below(30) as i32;
        s += 1;
    }
    for _ in 0..k_neg {
        tokens[slots[s]] = NEG0 + rng.below(30) as i32;
        s += 1;
    }
    TaskSample { tokens, label }
}

/// boolq-sim: "is key K in the passage?" The question token selects which
/// key matters; the passage may or may not contain it.
fn boolq(rng: &mut Rng, seq: usize) -> TaskSample {
    let which = rng.below(2) as i32; // Q0 or Q1
    let label = rng.below(2) as u8;
    let key = KEY0 + which;
    let decoy = KEY0 + (1 - which);
    let mut tokens: Vec<i32> = (0..seq).map(|_| bg_token(rng)).collect();
    tokens[0] = if which == 0 { Q0 } else { Q1 };
    // Always plant the decoy key (so "any key present" is not a shortcut).
    let dpos = 2 + rng.below(seq - 2);
    tokens[dpos] = decoy;
    if label == 1 {
        let mut kpos = 2 + rng.below(seq - 2);
        if kpos == dpos {
            kpos = if kpos + 1 < seq { kpos + 1 } else { 2 };
        }
        tokens[kpos] = key;
    }
    TaskSample { tokens, label }
}

/// qnli-sim: does the second half answer the first? Label by content-token
/// overlap of the two halves crossing a threshold.
fn qnli(rng: &mut Rng, seq: usize) -> TaskSample {
    let half = seq / 2;
    let label = rng.below(2) as u8;
    let first: Vec<i32> = (0..half - 1).map(|_| bg_token(rng)).collect();
    let mut tokens = first.clone();
    tokens.push(SEP);
    // overlap: copy tokens from the first half into the second
    let n_copy = if label == 1 { half / 2 } else { rng.below(2) };
    for i in 0..half {
        if i < n_copy {
            tokens.push(first[rng.below(first.len())]);
        } else {
            tokens.push(bg_token(rng));
        }
    }
    tokens.truncate(seq);
    while tokens.len() < seq {
        tokens.push(0);
    }
    TaskSample { tokens, label }
}

/// qqp-sim: duplicate-question detection. Second half is a shuffled copy
/// of the first (dup) or fresh background text (not dup).
fn qqp(rng: &mut Rng, seq: usize) -> TaskSample {
    let half = seq / 2;
    let label = rng.below(2) as u8;
    let first: Vec<i32> = (0..half).map(|_| bg_token(rng)).collect();
    let mut second = if label == 1 {
        let mut c = first.clone();
        rng.shuffle(&mut c);
        c
    } else {
        (0..half).map(|_| bg_token(rng)).collect()
    };
    let mut tokens = first;
    tokens.append(&mut second);
    TaskSample { tokens, label }
}

/// rte-sim: entailment as subset relation — every content token of the
/// (short) second segment appears in the first segment iff entailed.
fn rte(rng: &mut Rng, seq: usize) -> TaskSample {
    let prem_len = seq * 3 / 4;
    let hyp_len = seq - prem_len - 1;
    let label = rng.below(2) as u8;
    let prem: Vec<i32> = (0..prem_len).map(|_| bg_token(rng)).collect();
    let mut tokens = prem.clone();
    tokens.push(SEP);
    for i in 0..hyp_len {
        if label == 1 {
            tokens.push(prem[rng.below(prem.len())]);
        } else {
            // half supported, half novel -> not entailed
            if i % 2 == 0 {
                tokens.push(prem[rng.below(prem.len())]);
            } else {
                tokens.push(bg_token(rng));
            }
        }
    }
    TaskSample { tokens, label }
}

/// mnli-sim: 3-way by overlap fraction: high -> entail(0),
/// mid -> neutral(1), low -> contradict(2).
fn mnli(rng: &mut Rng, seq: usize) -> TaskSample {
    let half = seq / 2;
    let label = rng.below(3) as u8;
    let frac = match label {
        0 => 0.9,
        1 => 0.45,
        _ => 0.0,
    };
    let first: Vec<i32> = (0..half).map(|_| bg_token(rng)).collect();
    let mut tokens = first.clone();
    let n_copy = (half as f64 * frac) as usize;
    for i in 0..half {
        if i < n_copy {
            tokens.push(first[rng.below(first.len())]);
        } else {
            tokens.push(bg_token(rng));
        }
    }
    TaskSample { tokens, label }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_tasks_produce_valid_samples() {
        for task in Task::ALL {
            for idx in 0..50 {
                let s = task.sample(1, idx, 32);
                assert_eq!(s.tokens.len(), 32, "{}", task.name());
                assert!(s.tokens.iter().all(|&t| (0..VOCAB as i32).contains(&t)));
                assert!((s.label as usize) < task.n_classes());
            }
        }
    }

    #[test]
    fn labels_are_roughly_balanced() {
        for task in Task::ALL {
            let mut counts = vec![0usize; task.n_classes()];
            for idx in 0..600 {
                counts[task.sample(0, idx, 32).label as usize] += 1;
            }
            let lo = *counts.iter().min().unwrap() as f64;
            let hi = *counts.iter().max().unwrap() as f64;
            assert!(lo / hi > 0.6, "{}: {counts:?}", task.name());
        }
    }

    #[test]
    fn sst2_label_matches_token_counts() {
        for idx in 0..100 {
            let s = Task::Sst2.sample(0, idx, 32);
            let pos = s.tokens.iter().filter(|&&t| (POS0..POS0 + 30).contains(&t)).count();
            let neg = s.tokens.iter().filter(|&&t| (NEG0..NEG0 + 30).contains(&t)).count();
            assert_eq!(s.label == 1, pos > neg, "idx={idx} pos={pos} neg={neg}");
        }
    }

    #[test]
    fn boolq_label_matches_key_presence() {
        for idx in 0..100 {
            let s = Task::BoolQ.sample(0, idx, 32);
            let which = if s.tokens[0] == Q0 { 0 } else { 1 };
            let key = KEY0 + which;
            let present = s.tokens[1..].iter().any(|&t| t == key);
            assert_eq!(s.label == 1, present, "idx={idx}");
        }
    }

    #[test]
    fn qqp_duplicate_is_multiset_equal() {
        for idx in 0..100 {
            let s = Task::Qqp.sample(0, idx, 32);
            if s.label == 1 {
                let mut a = s.tokens[..16].to_vec();
                let mut b = s.tokens[16..].to_vec();
                a.sort();
                b.sort();
                assert_eq!(a, b);
            }
        }
    }

    #[test]
    fn rte_entailed_hypothesis_is_subset() {
        for idx in 0..100 {
            let s = Task::Rte.sample(0, idx, 32);
            if s.label == 1 {
                let prem: std::collections::HashSet<i32> = s.tokens[..24].iter().copied().collect();
                assert!(s.tokens[25..].iter().all(|t| prem.contains(t)));
            }
        }
    }

    #[test]
    fn task_name_round_trip() {
        for t in Task::ALL {
            assert_eq!(Task::from_name(t.name()), Some(t));
        }
    }
}
