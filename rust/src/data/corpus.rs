//! "wikitext2-sim": a synthetic language-modelling corpus for the Table 1
//! perplexity experiment. An order-1 Markov chain over the 512-token
//! vocabulary with Zipfian marginals and sparse, peaked transitions gives
//! the corpus learnable bigram structure: a trained tiny LM reaches a
//! perplexity well below the uniform bound, and quantizing it degrades
//! perplexity in the same ordering the paper reports.

use crate::util::rng::Rng;

pub const VOCAB: usize = 512;
/// Successors per state in the sparse transition table.
const SUCCESSORS: usize = 8;

/// Deterministic Markov-chain corpus generator.
pub struct MarkovCorpus {
    /// `succ[s][k]` = k-th successor token of state s
    succ: Vec<[u16; SUCCESSORS]>,
    /// cumulative probabilities over successors (shared shape for all s)
    cum: [f64; SUCCESSORS],
    /// probability of ignoring the chain and sampling background (noise)
    noise: f64,
}

impl MarkovCorpus {
    pub fn new(seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0xC0FFEE);
        let mut succ = Vec::with_capacity(VOCAB);
        for _ in 0..VOCAB {
            let mut row = [0u16; SUCCESSORS];
            for r in row.iter_mut() {
                // Zipfian-ish successor choice: favor low token ids.
                let u = rng.uniform();
                *r = ((VOCAB as f64) * u * u) as u16 % VOCAB as u16;
            }
            succ.push(row);
        }
        // Peaked successor distribution: p ~ 1/(k+1)^1.5, precomputed CDF.
        let mut w = [0.0f64; SUCCESSORS];
        for (k, wk) in w.iter_mut().enumerate() {
            *wk = 1.0 / ((k + 1) as f64).powf(1.5);
        }
        let total: f64 = w.iter().sum();
        let mut cum = [0.0f64; SUCCESSORS];
        let mut acc = 0.0;
        for k in 0..SUCCESSORS {
            acc += w[k] / total;
            cum[k] = acc;
        }
        Self { succ, cum, noise: 0.05 }
    }

    /// Generate a [batch, seq] token matrix, deterministic in `stream`.
    pub fn batch(&self, stream: u64, batch: usize, seq: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(batch * seq);
        for b in 0..batch {
            let mut rng = Rng::new(stream.wrapping_mul(0xA24BAED4963EE407).wrapping_add(b as u64));
            let mut state = rng.below(VOCAB) as u16;
            for _ in 0..seq {
                out.push(state as i32);
                state = if rng.uniform() < self.noise {
                    rng.below(VOCAB) as u16
                } else {
                    let u = rng.uniform();
                    let k = self.cum.iter().position(|&c| u <= c).unwrap_or(SUCCESSORS - 1);
                    self.succ[state as usize][k]
                };
            }
        }
        out
    }

    /// Entropy rate (nats/token) of the chain ignoring noise — the
    /// theoretical floor for the trained LM's loss, used by tests.
    pub fn entropy_floor(&self) -> f64 {
        // successor weights p_k
        let mut prev = 0.0;
        let mut h = 0.0;
        for &c in &self.cum {
            let p = c - prev;
            h -= p * p.ln();
            prev = c;
        }
        // plus the noise mixture's contribution (approximate upper floor)
        let n = self.noise;
        (1.0 - n) * h + n * (VOCAB as f64).ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_stream() {
        let c = MarkovCorpus::new(7);
        assert_eq!(c.batch(3, 4, 64), c.batch(3, 4, 64));
        assert_ne!(c.batch(3, 4, 64), c.batch(4, 4, 64));
    }

    #[test]
    fn tokens_in_vocab() {
        let c = MarkovCorpus::new(7);
        assert!(c.batch(0, 8, 64).iter().all(|&t| (0..VOCAB as i32).contains(&t)));
    }

    #[test]
    fn bigram_structure_exists() {
        // The empirical conditional entropy must be far below log(V):
        // that's what makes the corpus learnable.
        let c = MarkovCorpus::new(7);
        let toks = c.batch(0, 64, 128);
        let mut uni = vec![0f64; VOCAB];
        let mut big = std::collections::HashMap::<(i32, i32), f64>::new();
        for row in toks.chunks(128) {
            for w in row.windows(2) {
                uni[w[0] as usize] += 1.0;
                *big.entry((w[0], w[1])).or_default() += 1.0;
            }
        }
        let n: f64 = uni.iter().sum();
        let mut h_cond = 0.0;
        for ((a, _), c2) in &big {
            let p_joint = c2 / n;
            let p_cond = c2 / uni[*a as usize];
            h_cond -= p_joint * p_cond.ln();
        }
        assert!(h_cond < 0.7 * (VOCAB as f64).ln(), "H={h_cond}");
    }

    #[test]
    fn entropy_floor_is_sane() {
        let c = MarkovCorpus::new(7);
        let h = c.entropy_floor();
        assert!(h > 0.5 && h < (VOCAB as f64).ln(), "{h}");
    }
}
