//! Synthetic data substrate — stands in for the paper's HuggingFace
//! datasets (boolq/mnli/qnli/qqp/rte/sst2 and Wikitext2), which are not
//! available offline. Each task is a deterministic, seeded generator whose
//! label depends on a pattern a small transformer can learn (token
//! counting, co-occurrence, overlap, copy detection), so accuracy responds
//! to quantization the way the real benchmarks do: FP32 well above chance,
//! low-precision formats degrading smoothly, int saturating badly.

pub mod corpus;
pub mod tasks;

pub use corpus::MarkovCorpus;
pub use tasks::{Task, TaskSample};

/// A batch of classifier examples in the HLO artifact's input layout.
#[derive(Debug, Clone)]
pub struct Batch {
    /// row-major [batch, seq] token ids
    pub tokens: Vec<i32>,
    pub labels: Vec<i32>,
    pub batch: usize,
    pub seq: usize,
}

impl Batch {
    pub fn new(batch: usize, seq: usize) -> Self {
        Self {
            tokens: Vec::with_capacity(batch * seq),
            labels: Vec::with_capacity(batch),
            batch,
            seq,
        }
    }

    pub fn push(&mut self, sample: TaskSample) {
        assert_eq!(sample.tokens.len(), self.seq);
        self.tokens.extend_from_slice(&sample.tokens);
        self.labels.push(sample.label as i32);
    }

    pub fn is_full(&self) -> bool {
        self.labels.len() == self.batch
    }
}

/// Deterministic evaluation set: `n_batches` batches for (task, split).
/// Split 0 = train stream, split 1 = held-out eval.
pub fn batches(task: Task, split: u64, n_batches: usize, batch: usize, seq: usize) -> Vec<Batch> {
    let mut out = Vec::with_capacity(n_batches);
    for b in 0..n_batches {
        let mut bt = Batch::new(batch, seq);
        for i in 0..batch {
            let idx = (b * batch + i) as u64;
            bt.push(task.sample(split, idx, seq));
        }
        out.push(bt);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_are_deterministic() {
        let a = batches(Task::Sst2, 1, 2, 8, 32);
        let b = batches(Task::Sst2, 1, 2, 8, 32);
        assert_eq!(a[0].tokens, b[0].tokens);
        assert_eq!(a[1].labels, b[1].labels);
    }

    #[test]
    fn splits_differ() {
        let a = batches(Task::Sst2, 0, 1, 8, 32);
        let b = batches(Task::Sst2, 1, 1, 8, 32);
        assert_ne!(a[0].tokens, b[0].tokens);
    }

    #[test]
    fn batch_layout() {
        let bs = batches(Task::Qqp, 1, 3, 16, 32);
        assert_eq!(bs.len(), 3);
        for b in &bs {
            assert_eq!(b.tokens.len(), 16 * 32);
            assert_eq!(b.labels.len(), 16);
            assert!(b.is_full());
        }
    }
}
