//! Front-end: the model zoo and the MASE-IR builder (paper §3, "front-end
//! automatically performs model analysis and initializes software
//! attributes when constructing MASE IR").
//!
//! Ground truth for parameter layout and qtensor ordering is
//! `artifacts/manifest.json`, written by the AOT pipeline — the Rust side
//! never re-derives it, so L2 and L3 cannot drift apart.

pub mod manifest;

pub use manifest::{Manifest, ModelMeta};

use crate::formats::{FormatKind, Precision};
use crate::ir::{Graph, OpKind, TensorType};
use crate::util::rng::Rng;

/// Build the MASE IR graph for one model, mirroring the L2 transformer
/// (including the dataflow-specific `transpose`/`reorder` ops of Fig. 1d).
/// Values taking part in quantization search carry their qtensor index.
pub fn build_graph(meta: &ModelMeta) -> Graph {
    let mut g = Graph::new(&meta.name);
    let (b, s, d) = (meta.batch, meta.seq_len, meta.d_model);
    let q = |name: &str| -> Option<usize> { meta.qtensors.iter().position(|n| n == name) };

    let tokens = g.add_input("tokens", TensorType::fp32(vec![b, s]));
    let embed_w = g.new_value("embed", TensorType::fp32(vec![meta.vocab, d]), None);
    let mut x = g.add_op(OpKind::Embed, vec![tokens], vec![embed_w], "x0", TensorType::fp32(vec![b, s, d]), None);

    for l in 0..meta.n_layers {
        let p = format!("layer{l}.");
        // attention block
        let h = g.add_op(
            OpKind::LayerNorm,
            vec![x],
            vec![],
            &format!("{p}ln1"),
            TensorType::fp32(vec![b, s, d]),
            q(&format!("{p}a_attn_in")),
        );
        let w_qkv = g.new_value(
            &format!("{p}w_qkv"),
            TensorType::fp32(vec![d, 3 * d]),
            q(&format!("{p}w_qkv")),
        );
        let qkv = g.add_op(
            OpKind::Linear,
            vec![h],
            vec![w_qkv],
            &format!("{p}qkv"),
            TensorType::fp32(vec![b, s, 3 * d]),
            None,
        );
        // dataflow-specific stream reorder: row-stream -> head-major
        let heads = g.add_op(
            OpKind::Reorder,
            vec![qkv],
            vec![],
            &format!("{p}heads"),
            TensorType::fp32(vec![b, meta.n_heads, s, 3 * d / meta.n_heads]),
            None,
        );
        // K must stream column-major into QK^T
        let kt = g.add_op(
            OpKind::Transpose,
            vec![heads],
            vec![],
            &format!("{p}kT"),
            TensorType::fp32(vec![b, meta.n_heads, d / meta.n_heads, s]),
            None,
        );
        let att = g.add_op(
            OpKind::Attention,
            vec![heads, kt],
            vec![],
            &format!("{p}att"),
            TensorType::fp32(vec![b, s, d]),
            q(&format!("{p}a_proj_in")),
        );
        let w_proj = g.new_value(
            &format!("{p}w_proj"),
            TensorType::fp32(vec![d, d]),
            q(&format!("{p}w_proj")),
        );
        let proj = g.add_op(
            OpKind::Linear,
            vec![att],
            vec![w_proj],
            &format!("{p}proj"),
            TensorType::fp32(vec![b, s, d]),
            None,
        );
        let res1 = g.add_op(
            OpKind::Add,
            vec![x, proj],
            vec![],
            &format!("{p}res1"),
            TensorType::fp32(vec![b, s, d]),
            None,
        );
        // FFN block
        let h2 = g.add_op(
            OpKind::LayerNorm,
            vec![res1],
            vec![],
            &format!("{p}ln2"),
            TensorType::fp32(vec![b, s, d]),
            q(&format!("{p}a_fc1_in")),
        );
        let w_fc1 = g.new_value(
            &format!("{p}w_fc1"),
            TensorType::fp32(vec![d, meta.d_ff]),
            q(&format!("{p}w_fc1")),
        );
        let fc1 = g.add_op(
            OpKind::Linear,
            vec![h2],
            vec![w_fc1],
            &format!("{p}fc1"),
            TensorType::fp32(vec![b, s, meta.d_ff]),
            None,
        );
        let gelu = g.add_op(
            OpKind::Gelu,
            vec![fc1],
            vec![],
            &format!("{p}gelu"),
            TensorType::fp32(vec![b, s, meta.d_ff]),
            q(&format!("{p}a_fc2_in")),
        );
        let w_fc2 = g.new_value(
            &format!("{p}w_fc2"),
            TensorType::fp32(vec![meta.d_ff, d]),
            q(&format!("{p}w_fc2")),
        );
        let fc2 = g.add_op(
            OpKind::Linear,
            vec![gelu],
            vec![w_fc2],
            &format!("{p}fc2"),
            TensorType::fp32(vec![b, s, d]),
            None,
        );
        x = g.add_op(
            OpKind::Add,
            vec![res1, fc2],
            vec![],
            &format!("{p}res2"),
            TensorType::fp32(vec![b, s, d]),
            None,
        );
    }

    let lnf = g.add_op(
        OpKind::LayerNorm,
        vec![x],
        vec![],
        "lnf",
        TensorType::fp32(vec![b, s, d]),
        if meta.kind == "lm" { q("a_head_in") } else { None },
    );
    let head_in = if meta.kind == "lm" {
        lnf
    } else {
        g.add_op(
            OpKind::MeanPool,
            vec![lnf],
            vec![],
            "pooled",
            TensorType::fp32(vec![b, d]),
            q("a_head_in"),
        )
    };
    let out_dim = if meta.kind == "lm" { meta.vocab } else { meta.n_classes };
    let head_w = g.new_value("head_w", TensorType::fp32(vec![d, out_dim]), q("head_w"));
    let logits_shape = if meta.kind == "lm" { vec![b, s, out_dim] } else { vec![b, out_dim] };
    let logits = g.add_op(
        OpKind::Linear,
        vec![head_in],
        vec![head_w],
        "logits",
        TensorType::fp32(logits_shape.clone()),
        None,
    );
    let out = g.add_op(OpKind::Output, vec![logits], vec![], "out", TensorType::fp32(logits_shape), None);
    g.outputs.push(out);
    g
}

/// Injected outlier-channel config — must match `model.py`
/// (`OUTLIER_CHANNELS`, `OUTLIER_BASE_GAIN`); see DESIGN.md §3.
pub const OUTLIER_CHANNELS: usize = 4;
pub const OUTLIER_BASE_GAIN: f32 = 16.0;

/// Initialize a flat parameter vector for pretraining (Glorot-ish normal,
/// ones for LN gains, zeros for biases) — mirrors `model.init_params`.
///
/// Weight rows consuming the injected outlier channels (w_qkv, w_fc1) are
/// scaled by 1/gain so the initial forward behaves like the outlier-free
/// model: training stays stable while activations keep the outliers the
/// quantizers must cope with.
pub fn init_params(meta: &ModelMeta, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::with_capacity(meta.param_size);
    for spec in &meta.param_spec {
        let n: usize = spec.shape.iter().product();
        let name = &spec.name;
        if name.ends_with("_b") {
            out.extend(std::iter::repeat(0.0f32).take(n));
        } else if name.ends_with("_g") {
            out.extend(std::iter::repeat(1.0f32).take(n));
        } else {
            let fan_in = spec.shape.first().copied().unwrap_or(1) as f64;
            let fan_out = spec.shape.last().copied().unwrap_or(1) as f64;
            let std = (2.0 / (fan_in + fan_out)).sqrt();
            let start = out.len();
            out.extend((0..n).map(|_| (rng.normal() * std) as f32));
            if name.contains(".w_qkv") || name.contains(".w_fc1") {
                let layer: usize = name
                    .split('.')
                    .next()
                    .and_then(|p| p.strip_prefix("layer"))
                    .and_then(|l| l.parse().ok())
                    .unwrap_or(0);
                let gain = OUTLIER_BASE_GAIN * (1.0 + layer as f32);
                let cols = spec.shape[1];
                for r in 0..OUTLIER_CHANNELS.min(spec.shape[0]) {
                    for c in 0..cols {
                        out[start + r * cols + c] /= gain;
                    }
                }
            }
        }
    }
    assert_eq!(out.len(), meta.param_size);
    out
}

/// Apply a quantization solution to the IR: set format and per-tensor
/// precision on every searchable value (the `quantize` pass's IR side).
pub fn apply_quant_to_graph(g: &mut Graph, fmt: FormatKind, bits: &[f32], fracs: &[f32]) {
    for v in g.values.iter_mut() {
        if let Some(qi) = v.qtensor {
            v.ty.format = fmt;
            v.ty.precision = Precision::new(bits[qi], fracs.get(qi).copied().unwrap_or(0.0));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta() -> ModelMeta {
        ModelMeta::synthetic("test-sim", 2, 32, 2, 512, 32, 4, "classifier", 64)
    }

    #[test]
    fn graph_has_expected_qtensors() {
        let m = meta();
        let g = build_graph(&m);
        let qs = g.qtensor_values();
        assert_eq!(qs.len(), m.qtensors.len());
        assert_eq!(qs.len(), 8 * m.n_layers + 2);
        // every qtensor index is used exactly once
        for (i, &v) in qs.iter().enumerate() {
            assert_eq!(g.value(v).qtensor, Some(i));
        }
    }

    #[test]
    fn graph_verifies() {
        let g = build_graph(&meta());
        let errs = crate::ir::verify(&g);
        assert!(errs.is_empty(), "{errs:?}");
    }

    #[test]
    fn graph_has_dataflow_ops() {
        let g = build_graph(&meta());
        assert!(g.ops.iter().any(|o| o.kind == OpKind::Transpose));
        assert!(g.ops.iter().any(|o| o.kind == OpKind::Reorder));
    }

    #[test]
    fn dag_size_scales_with_layers() {
        let g2 = build_graph(&meta());
        let m6 = ModelMeta::synthetic("big", 6, 32, 2, 512, 32, 4, "classifier", 64);
        let g6 = build_graph(&m6);
        assert!(g6.dag_size() > g2.dag_size());
        // module-level: ~12 ops per layer, not thousands (Table 3 claim)
        assert!(g6.dag_size() < 12 * 6 + 10);
    }

    #[test]
    fn init_params_layout() {
        let m = meta();
        let p = init_params(&m, 0);
        assert_eq!(p.len(), m.param_size);
        // LN gains start at exactly 1.0
        let ln_spec = m.param_spec.iter().find(|s| s.name.ends_with("ln1_g")).unwrap();
        assert!(p[ln_spec.offset..ln_spec.offset + 4].iter().all(|&v| v == 1.0));
    }

    #[test]
    fn apply_quant_sets_types() {
        let m = meta();
        let mut g = build_graph(&m);
        let bits = vec![4.0f32; m.qtensors.len()];
        apply_quant_to_graph(&mut g, FormatKind::MxInt, &bits, &[]);
        for &v in &g.qtensor_values() {
            assert_eq!(g.value(v).ty.format, FormatKind::MxInt);
            assert_eq!(g.value(v).ty.precision.bits, 4.0);
        }
    }
}
