//! Reader for `artifacts/manifest.json` — the contract between the AOT
//! pipeline (python) and the coordinator (rust).

use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
}

#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub name: String,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub seq_len: usize,
    pub n_classes: usize,
    pub kind: String,
    pub batch: usize,
    pub param_size: usize,
    pub param_spec: Vec<ParamSpec>,
    pub qtensors: Vec<String>,
    /// artifact key (e.g. "eval_mxint") -> file name
    pub artifacts: BTreeMap<String, String>,
}

impl ModelMeta {
    /// Purely synthetic metadata for unit tests (no artifact files).
    pub fn synthetic(
        name: &str,
        n_layers: usize,
        d_model: usize,
        n_heads: usize,
        vocab: usize,
        seq_len: usize,
        n_classes: usize,
        kind: &str,
        batch: usize,
    ) -> Self {
        let d_ff = 4 * d_model;
        let mut param_spec = Vec::new();
        let mut off = 0usize;
        let push = |spec: &mut Vec<ParamSpec>, n: &str, shape: Vec<usize>, off: &mut usize| {
            let sz: usize = shape.iter().product();
            spec.push(ParamSpec { name: n.to_string(), shape, offset: *off });
            *off += sz;
        };
        push(&mut param_spec, "embed", vec![vocab, d_model], &mut off);
        push(&mut param_spec, "pos", vec![seq_len, d_model], &mut off);
        for i in 0..n_layers {
            let p = format!("layer{i}.");
            push(&mut param_spec, &format!("{p}ln1_g"), vec![d_model], &mut off);
            push(&mut param_spec, &format!("{p}ln1_b"), vec![d_model], &mut off);
            push(&mut param_spec, &format!("{p}w_qkv"), vec![d_model, 3 * d_model], &mut off);
            push(&mut param_spec, &format!("{p}b_qkv"), vec![3 * d_model], &mut off);
            push(&mut param_spec, &format!("{p}w_proj"), vec![d_model, d_model], &mut off);
            push(&mut param_spec, &format!("{p}b_proj"), vec![d_model], &mut off);
            push(&mut param_spec, &format!("{p}ln2_g"), vec![d_model], &mut off);
            push(&mut param_spec, &format!("{p}ln2_b"), vec![d_model], &mut off);
            push(&mut param_spec, &format!("{p}w_fc1"), vec![d_model, d_ff], &mut off);
            push(&mut param_spec, &format!("{p}b_fc1"), vec![d_ff], &mut off);
            push(&mut param_spec, &format!("{p}w_fc2"), vec![d_ff, d_model], &mut off);
            push(&mut param_spec, &format!("{p}b_fc2"), vec![d_model], &mut off);
        }
        push(&mut param_spec, "lnf_g", vec![d_model], &mut off);
        push(&mut param_spec, "lnf_b", vec![d_model], &mut off);
        let out = if kind == "lm" { vocab } else { n_classes };
        push(&mut param_spec, "head_w", vec![d_model, out], &mut off);
        push(&mut param_spec, "head_b", vec![out], &mut off);

        let mut qtensors = Vec::new();
        for i in 0..n_layers {
            let p = format!("layer{i}.");
            for n in ["a_attn_in", "w_qkv", "a_proj_in", "w_proj", "a_fc1_in", "w_fc1", "a_fc2_in", "w_fc2"] {
                qtensors.push(format!("{p}{n}"));
            }
        }
        qtensors.push("a_head_in".into());
        qtensors.push("head_w".into());

        Self {
            name: name.to_string(),
            n_layers,
            d_model,
            n_heads,
            d_ff,
            vocab,
            seq_len,
            n_classes,
            kind: kind.to_string(),
            batch,
            param_size: off,
            param_spec,
            qtensors,
            artifacts: BTreeMap::new(),
        }
    }

    pub fn artifact(&self, key: &str) -> Result<&str> {
        self.artifacts
            .get(key)
            .map(|s| s.as_str())
            .ok_or_else(|| anyhow!("model {} has no artifact '{key}'", self.name))
    }

    pub fn num_qtensors(&self) -> usize {
        self.qtensors.len()
    }
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub block_shape: (usize, usize),
    pub shared_exponent_bits: u32,
    pub quant_refs: BTreeMap<String, String>,
    pub models: BTreeMap<String, ModelMeta>,
}

impl Manifest {
    /// The synthetic model zoo — dimensions mirrored 1:1 from
    /// `python/compile/model.py::MODEL_ZOO` (drift there is caught by the
    /// `parses_real_manifest_if_present` round-trip test), plus a
    /// `toy-sim` model small enough for CI smoke runs. Used by
    /// CPU-backend sessions on hosts without `artifacts/manifest.json`:
    /// the packed interpreter needs no artifacts, only the layouts.
    pub fn synthetic() -> Manifest {
        let clf = |name: &str, layers: usize, d: usize, heads: usize| {
            ModelMeta::synthetic(name, layers, d, heads, 512, 32, 4, "classifier", 64)
        };
        let zoo = [
            clf("bert-base-sim", 3, 64, 4),
            clf("bert-large-sim", 5, 96, 6),
            clf("opt-125m-sim", 2, 32, 2),
            clf("opt-350m-sim", 3, 48, 3),
            clf("opt-1.3b-sim", 4, 64, 4),
            clf("opt-2.7b-sim", 5, 96, 4),
            clf("opt-6.7b-sim", 6, 128, 8),
            clf("llama-7b-sim", 4, 64, 4),
            clf("vicuna-7b-sim", 4, 64, 4),
            clf("alpaca-7b-sim", 4, 64, 4),
            ModelMeta::synthetic("llama-sim", 4, 64, 4, 512, 64, 4, "lm", 16),
            // CI smoke model (not in the python zoo): one layer, tiny batch
            ModelMeta::synthetic("toy-sim", 1, 32, 2, 512, 16, 4, "classifier", 16),
            // CI decode-smoke model (not in the python zoo): the LM twin of
            // toy-sim, seq 32 so a short prompt + a few generated tokens
            // still cross the position-16 quantizer block boundary.
            ModelMeta::synthetic("toy-lm", 1, 32, 2, 512, 32, 4, "lm", 16),
        ];
        Manifest {
            block_shape: crate::formats::BLOCK_SHAPE,
            shared_exponent_bits: crate::formats::SHARED_EXPONENT_BITS,
            quant_refs: BTreeMap::new(),
            models: zoo.into_iter().map(|m| (m.name.clone(), m)).collect(),
        }
    }

    pub fn load(artifacts_dir: &Path) -> Result<Manifest> {
        let path = artifacts_dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        Self::from_json(&j)
    }

    pub fn from_json(j: &Json) -> Result<Manifest> {
        let get = |o: &Json, k: &str| -> Result<Json> {
            o.get(k).cloned().ok_or_else(|| anyhow!("manifest missing key '{k}'"))
        };
        let bs = get(j, "block_shape")?;
        let bsa = bs.as_arr().ok_or_else(|| anyhow!("block_shape not array"))?;
        let block_shape = (
            bsa[0].as_usize().unwrap_or(16),
            bsa[1].as_usize().unwrap_or(2),
        );
        let mut quant_refs = BTreeMap::new();
        if let Some(q) = j.get("quant_refs").and_then(|q| q.as_obj()) {
            for (k, v) in q {
                quant_refs.insert(k.clone(), v.as_str().unwrap_or("").to_string());
            }
        }
        let mut models = BTreeMap::new();
        let mobj = get(j, "models")?;
        for (name, m) in mobj.as_obj().ok_or_else(|| anyhow!("models not object"))? {
            let u = |k: &str| -> Result<usize> {
                m.get(k).and_then(|v| v.as_usize()).ok_or_else(|| anyhow!("model {name}: bad {k}"))
            };
            let mut param_spec = Vec::new();
            for e in m.get("param_spec").and_then(|v| v.as_arr()).unwrap_or(&[]) {
                param_spec.push(ParamSpec {
                    name: e.get("name").and_then(|v| v.as_str()).unwrap_or("").to_string(),
                    shape: e
                        .get("shape")
                        .and_then(|v| v.as_arr())
                        .map(|a| a.iter().filter_map(|d| d.as_usize()).collect())
                        .unwrap_or_default(),
                    offset: e.get("offset").and_then(|v| v.as_usize()).unwrap_or(0),
                });
            }
            let qtensors = m
                .get("qtensors")
                .and_then(|v| v.as_arr())
                .map(|a| a.iter().filter_map(|s| s.as_str().map(String::from)).collect())
                .unwrap_or_default();
            let mut artifacts = BTreeMap::new();
            if let Some(a) = m.get("artifacts").and_then(|v| v.as_obj()) {
                for (k, v) in a {
                    artifacts.insert(k.clone(), v.as_str().unwrap_or("").to_string());
                }
            }
            models.insert(
                name.clone(),
                ModelMeta {
                    name: name.clone(),
                    n_layers: u("n_layers")?,
                    d_model: u("d_model")?,
                    n_heads: u("n_heads")?,
                    d_ff: u("d_ff")?,
                    vocab: u("vocab")?,
                    seq_len: u("seq_len")?,
                    n_classes: u("n_classes")?,
                    kind: m.get("kind").and_then(|v| v.as_str()).unwrap_or("classifier").to_string(),
                    batch: u("batch")?,
                    param_size: u("param_size")?,
                    param_spec,
                    qtensors,
                    artifacts,
                },
            );
        }
        Ok(Manifest {
            block_shape,
            shared_exponent_bits: j
                .get("shared_exponent_bits")
                .and_then(|v| v.as_usize())
                .unwrap_or(8) as u32,
            quant_refs,
            models,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelMeta> {
        self.models.get(name).ok_or_else(|| anyhow!("unknown model '{name}'"))
    }

    /// The ten classifier simulants (Figs. 5/7/8), sorted by name.
    pub fn classifiers(&self) -> Vec<&ModelMeta> {
        self.models.values().filter(|m| m.kind == "classifier").collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_meta_is_consistent() {
        let m = ModelMeta::synthetic("t", 2, 32, 2, 512, 32, 4, "classifier", 64);
        assert_eq!(m.num_qtensors(), 18);
        // offsets dense
        let mut off = 0;
        for s in &m.param_spec {
            assert_eq!(s.offset, off);
            off += s.shape.iter().product::<usize>();
        }
        assert_eq!(off, m.param_size);
    }

    #[test]
    fn synthetic_manifest_mirrors_the_zoo() {
        let m = Manifest::synthetic();
        assert_eq!(m.block_shape, (16, 2));
        assert_eq!(m.shared_exponent_bits, 8);
        assert_eq!(m.models.len(), 13);
        assert_eq!(m.classifiers().len(), 11, "10 zoo classifiers + toy-sim");
        let toy = m.model("toy-lm").unwrap();
        assert_eq!((toy.kind.as_str(), toy.seq_len, toy.batch), ("lm", 32, 16));
        let opt = m.model("opt-125m-sim").unwrap();
        assert_eq!((opt.n_layers, opt.d_model, opt.n_heads), (2, 32, 2));
        assert_eq!(opt.num_qtensors(), 18);
        let lm = m.model("llama-sim").unwrap();
        assert_eq!((lm.kind.as_str(), lm.seq_len, lm.batch), ("lm", 64, 16));
        // every model is (16, 2)-tileable for the packed CPU interpreter
        for meta in m.models.values() {
            assert_eq!(meta.batch % 16, 0, "{}", meta.name);
            assert_eq!(meta.seq_len % 16, 0, "{}", meta.name);
            assert_eq!(meta.d_model % 16, 0, "{}", meta.name);
        }
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.block_shape, (16, 2));
        assert_eq!(m.shared_exponent_bits, 8);
        assert!(m.models.len() >= 11);
        let opt = m.model("opt-125m-sim").unwrap();
        assert_eq!(opt.num_qtensors(), 8 * opt.n_layers + 2);
        assert!(opt.artifact("eval_mxint").is_ok());
        // synthetic meta must agree with the python-generated one
        let syn = ModelMeta::synthetic(
            "opt-125m-sim",
            opt.n_layers,
            opt.d_model,
            opt.n_heads,
            opt.vocab,
            opt.seq_len,
            opt.n_classes,
            &opt.kind,
            opt.batch,
        );
        assert_eq!(syn.param_size, opt.param_size, "param layout drift vs python");
        assert_eq!(syn.qtensors, opt.qtensors, "qtensor order drift vs python");
        let names: Vec<_> = syn.param_spec.iter().map(|s| &s.name).collect();
        let names2: Vec<_> = opt.param_spec.iter().map(|s| &s.name).collect();
        assert_eq!(names, names2);
    }

    #[test]
    fn classifiers_filter() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.classifiers().len(), 10);
    }
}
