//! The `.mxa` packed-weight artifact: a content-addressed binary
//! container that moves [`super::layout::PackedTensor`]s between
//! processes, so a warm session loads packed weights with **zero
//! re-quantize and zero re-pack** (the ROADMAP "serving restarts in
//! milliseconds" substrate).
//!
//! ## On-disk layout
//!
//! ```text
//! offset 0    "MXA1 " + 16 lowercase hex digits (manifest byte length) + "\n"   (22 bytes)
//! offset 22   manifest: one JSON object (crate::util::json rendering)
//! ...         zero padding to the next 64-byte boundary  ("data base")
//! data base   chunk 0, chunk 1, ... — each chunk starts 64-byte aligned
//!             (mmap-friendly), zero-padded between chunks
//! ```
//!
//! The manifest carries, per tensor, the exact [`ElemLayout`] parameters
//! (format tag, resolved knob/frac, element bits, shared-exponent bits —
//! block geometry and padding rules follow from those via the layout
//! module's single set of equations), the tensor shape, an FNV-1a/64
//! hash of the *source* f32 weights (little-endian bytes), and indices
//! into a chunk table. Block formats store two chunks (shared-exponent
//! bytes, then packed `u64` words as little-endian bytes); element-wise
//! formats store only the words chunk. Every chunk entry records its
//! offset **relative to the data base**, byte length, and FNV-1a/64 hash.
//!
//! Per the PR 2 convention, every integer in the manifest crosses JSON as
//! a fixed-width 16-digit lowercase hex string (`{:016x}`), never a lossy
//! f64 number; signed fields use the two's-complement `u64` bit pattern.
//!
//! The **artifact content hash** is FNV-1a/64 over the manifest bytes.
//! Since the manifest embeds every chunk hash, every layout and every
//! source hash, it content-addresses the entire artifact — `CacheStore`
//! eval scopes append it so cached objectives are keyed to the exact
//! weight bits they were measured on.
//!
//! ## Failure discipline
//!
//! Loading **fails closed**: a bad magic/version/schema, a malformed or
//! truncated manifest, an out-of-bounds or misaligned chunk, a length
//! mismatch against the layout's own sizing equations, or a chunk hash
//! mismatch all return an error *naming the offending tensor or chunk* —
//! never a silently partial weight set. (Contrast `CacheStore`, which
//! fails *open* to a cold cache: stale memos are recomputable, wrong
//! weights are not.)

use super::layout::{ElemLayout, PackedTensor, GROUP_ELEMS};
use crate::formats::{FormatKind, FormatSpec, Precision, BLOCK_SHAPE};
use crate::util::json::Json;
use crate::util::{hex16, hex_u64};
use anyhow::{anyhow, bail, ensure, Context, Result};
use std::collections::BTreeMap;
use std::io::Read;
use std::path::Path;
use std::sync::Arc;

/// Magic string of the fixed-size first line (includes the version).
pub const ARTIFACT_MAGIC: &str = "MXA1 ";
/// Manifest schema tag.
pub const ARTIFACT_SCHEMA: &str = "mase-packed-artifact";
/// Container version. Bump on any change to the header, manifest key
/// set, chunk encoding, or the packed bit layouts themselves; old
/// readers then refuse the file (fail closed — unlike the eval cache,
/// wrong weights are not recomputable).
pub const ARTIFACT_VERSION: u64 = 1;
/// Chunk (and data-base) alignment in bytes.
pub const CHUNK_ALIGN: u64 = 64;
/// Header line length: `"MXA1 "` + 16 hex digits + `"\n"`.
pub const HEADER_LEN: usize = 22;

// ------------------------------------------------------------ hashing --

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Incremental FNV-1a/64 — the container's only hash. Chunks are hashed
/// streaming, sub-buffer by sub-buffer, so validation never needs a
/// second pass over the bytes.
#[derive(Debug, Clone, Copy)]
pub struct Fnv1a(u64);

impl Fnv1a {
    pub fn new() -> Fnv1a {
        Fnv1a(FNV_OFFSET)
    }

    pub fn update(&mut self, bytes: &[u8]) {
        let mut h = self.0;
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
        self.0 = h;
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv1a {
    fn default() -> Self {
        Self::new()
    }
}

/// One-shot FNV-1a/64 of a byte slice.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = Fnv1a::new();
    h.update(bytes);
    h.finish()
}

/// Content hash of a source f32 weight vector: FNV-1a/64 over its
/// little-endian bytes. This keys artifact tensors to the exact bits
/// they were packed from, so a loader can prove an artifact tensor
/// matches the weights a session would otherwise pack in memory.
pub fn source_hash(weights: &[f32]) -> u64 {
    let mut h = Fnv1a::new();
    let mut buf = [0u8; 4];
    for v in weights {
        buf.copy_from_slice(&v.to_le_bytes());
        h.update(&buf);
    }
    h.finish()
}

// ------------------------------------------------- shared descriptors --

/// Per-tensor descriptor — the ONE struct both the `mase pack` JSON
/// manifest and the `.mxa` manifest render through, so the two surfaces
/// can never disagree about a tensor's layout fields.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorDesc {
    pub name: String,
    /// `"weight"` (matmul parameter) or `"embed"` (embedding table).
    pub kind: String,
    pub rows: usize,
    pub cols: usize,
    pub layout: ElemLayout,
    /// FNV-1a/64 of the source f32 weights ([`source_hash`]).
    pub source_hash: u64,
}

impl TensorDesc {
    /// Describe a packed tensor built from `source` f32 weights.
    pub fn for_tensor(name: &str, kind: &str, t: &PackedTensor, source: &[f32]) -> TensorDesc {
        TensorDesc {
            name: name.to_string(),
            kind: kind.to_string(),
            rows: t.rows,
            cols: t.cols,
            layout: t.layout,
            source_hash: source_hash(source),
        }
    }

    /// The shared JSON rendering (integers as fixed-width hex). Callers
    /// may extend the returned object with surface-specific fields
    /// (chunk indices for `.mxa`, analytic/packed bit counts for the
    /// pack manifest) but never re-render these.
    pub fn to_json(&self) -> BTreeMap<String, Json> {
        let mut o = BTreeMap::new();
        o.insert("name".into(), Json::Str(self.name.clone()));
        o.insert("kind".into(), Json::Str(self.kind.clone()));
        o.insert("rows".into(), Json::Str(hex16(self.rows as u64)));
        o.insert("cols".into(), Json::Str(hex16(self.cols as u64)));
        o.insert("layout".into(), layout_to_json(&self.layout));
        o.insert("source_hash".into(), Json::Str(hex16(self.source_hash)));
        o
    }

    fn from_json(j: &Json) -> Result<TensorDesc> {
        let name = j
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("tensor record missing name"))?
            .to_string();
        let field = |k: &str| -> Result<u64> {
            j.get(k)
                .and_then(Json::as_str)
                .and_then(hex_u64)
                .ok_or_else(|| anyhow!("tensor {name:?}: bad or missing field {k:?}"))
        };
        let kind = j
            .get("kind")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("tensor {name:?}: missing kind"))?
            .to_string();
        let layout = layout_from_json(j.get("layout").unwrap_or(&Json::Null))
            .with_context(|| format!("tensor {name:?}"))?;
        Ok(TensorDesc {
            kind,
            rows: field("rows")? as usize,
            cols: field("cols")? as usize,
            layout,
            source_hash: field("source_hash")?,
            name,
        })
    }
}

fn layout_to_json(l: &ElemLayout) -> Json {
    let mut o = BTreeMap::new();
    o.insert("fmt".to_string(), Json::Str(l.fmt.name().to_string()));
    o.insert("knob".to_string(), Json::Str(hex16(l.knob as i64 as u64)));
    o.insert("frac".to_string(), Json::Str(hex16(l.frac as i64 as u64)));
    o.insert("elem_bits".to_string(), Json::Str(hex16(l.elem_bits as u64)));
    o.insert("shared_exp_bits".to_string(), Json::Str(hex16(l.shared_exp_bits as u64)));
    Json::Obj(o)
}

/// Rebuild an [`ElemLayout`] from its manifest record — through
/// [`ElemLayout::new`], never by trusting the stored derived fields:
/// the stored `elem_bits`/`shared_exp_bits` must then MATCH what the
/// layout equations produce, or the record is corrupt/incompatible
/// (fail closed).
fn layout_from_json(j: &Json) -> Result<ElemLayout> {
    let s = |k: &str| -> Result<u64> {
        j.get(k)
            .and_then(Json::as_str)
            .and_then(hex_u64)
            .ok_or_else(|| anyhow!("layout record: bad or missing field {k:?}"))
    };
    let fmt_name =
        j.get("fmt").and_then(Json::as_str).ok_or_else(|| anyhow!("layout record: missing fmt"))?;
    let fmt = FormatKind::from_name(fmt_name)
        .ok_or_else(|| anyhow!("layout record: unknown format {fmt_name:?}"))?;
    let knob = s("knob")? as i64 as i32;
    let frac = s("frac")? as i64 as i32;
    let rebuilt = ElemLayout::new(fmt, Precision::new(knob as f32, frac as f32));
    ensure!(
        rebuilt.knob == knob
            && rebuilt.frac == frac
            && rebuilt.elem_bits as u64 == s("elem_bits")?
            && rebuilt.shared_exp_bits as u64 == s("shared_exp_bits")?,
        "layout record (fmt {fmt_name}, knob {knob}, frac {frac}) does not match this \
         build's layout equations — incompatible or corrupt artifact"
    );
    Ok(rebuilt)
}

/// Exps/words sizes the layout equations demand for a tensor shape —
/// duplicated from `pack`'s allocation arithmetic so the reader can
/// reject chunks of the wrong length before decoding anything.
fn expected_sizes(layout: &ElemLayout, rows: usize, cols: usize) -> (usize, usize) {
    let (br, bc) = BLOCK_SHAPE;
    if layout.fmt.is_block_format() {
        let blocks = (rows / br) * (cols / bc);
        (blocks, blocks * layout.words_per_group(GROUP_ELEMS))
    } else {
        let n = rows * cols;
        let wpg = layout.words_per_group(GROUP_ELEMS);
        let rem = n % GROUP_ELEMS;
        let tail = if rem > 0 { layout.words_per_group(rem) } else { 0 };
        (0, (n / GROUP_ELEMS) * wpg + tail)
    }
}

// -------------------------------------------------------------- writer --

struct ChunkRef {
    off: u64,
    len: u64,
    fnv: u64,
}

struct TensorEntry {
    desc: TensorDesc,
    /// Chunk-table index of the shared-exponent bytes (block formats).
    exps_chunk: Option<usize>,
    words_chunk: usize,
}

/// Builds and serializes one `.mxa` artifact.
pub struct ArtifactWriter {
    model: String,
    spec: FormatSpec,
    tensors: Vec<TensorEntry>,
    chunks: Vec<ChunkRef>,
    /// Concatenated chunk payloads, each 64-byte aligned relative to the
    /// data base.
    data: Vec<u8>,
}

impl ArtifactWriter {
    /// `model` names the graph the weights belong to; `spec` is the
    /// uniform format the pack ran at (individual tensors may still
    /// carry per-tensor layouts — embeddings stay fp32, for example).
    pub fn new(model: &str, spec: FormatSpec) -> ArtifactWriter {
        ArtifactWriter {
            model: model.to_string(),
            spec,
            tensors: Vec::new(),
            chunks: Vec::new(),
            data: Vec::new(),
        }
    }

    fn push_chunk(&mut self, bytes: &[u8]) -> usize {
        // align the data cursor, then append
        let pad = (CHUNK_ALIGN - (self.data.len() as u64 % CHUNK_ALIGN)) % CHUNK_ALIGN;
        self.data.resize(self.data.len() + pad as usize, 0u8);
        let off = self.data.len() as u64;
        self.data.extend_from_slice(bytes);
        self.chunks.push(ChunkRef { off, len: bytes.len() as u64, fnv: fnv1a(bytes) });
        self.chunks.len() - 1
    }

    /// Append one packed tensor under `desc`. Tensor names must be
    /// unique; insertion order is the chunk order on disk.
    pub fn add_tensor(&mut self, desc: TensorDesc, t: &PackedTensor) -> Result<()> {
        ensure!(
            desc.rows == t.rows && desc.cols == t.cols && desc.layout == t.layout,
            "descriptor for {:?} disagrees with the packed tensor",
            desc.name
        );
        ensure!(
            self.tensors.iter().all(|e| e.desc.name != desc.name),
            "duplicate tensor name {:?}",
            desc.name
        );
        let exps_chunk =
            if t.layout.fmt.is_block_format() { Some(self.push_chunk(&t.exps)) } else { None };
        let word_bytes: Vec<u8> = t.words.iter().flat_map(|w| w.to_le_bytes()).collect();
        let words_chunk = self.push_chunk(&word_bytes);
        self.tensors.push(TensorEntry { desc, exps_chunk, words_chunk });
        Ok(())
    }

    /// The descriptors added so far, in chunk order. `mase pack` renders
    /// its JSON manifest's weight rows from these — the same structs the
    /// `.mxa` manifest serializes — so the two surfaces cannot drift.
    pub fn tensor_descs(&self) -> impl Iterator<Item = &TensorDesc> {
        self.tensors.iter().map(|e| &e.desc)
    }

    fn manifest(&self) -> Json {
        let mut root = BTreeMap::new();
        root.insert("schema".to_string(), Json::Str(ARTIFACT_SCHEMA.to_string()));
        root.insert("version".to_string(), Json::Str(hex16(ARTIFACT_VERSION)));
        root.insert("model".to_string(), Json::Str(self.model.clone()));
        let mut fs = BTreeMap::new();
        fs.insert("kind".to_string(), Json::Str(self.spec.kind.name().to_string()));
        fs.insert("bits".to_string(), Json::Str(hex16((self.spec.bits as f64).to_bits())));
        fs.insert("frac".to_string(), Json::Str(hex16((self.spec.frac as f64).to_bits())));
        root.insert("format".to_string(), Json::Obj(fs));
        let tensors: Vec<Json> = self
            .tensors
            .iter()
            .map(|e| {
                let mut o = e.desc.to_json();
                if let Some(i) = e.exps_chunk {
                    o.insert("exps_chunk".into(), Json::Str(hex16(i as u64)));
                }
                o.insert("words_chunk".into(), Json::Str(hex16(e.words_chunk as u64)));
                Json::Obj(o)
            })
            .collect();
        root.insert("tensors".to_string(), Json::Arr(tensors));
        let chunks: Vec<Json> = self
            .chunks
            .iter()
            .map(|c| {
                let mut o = BTreeMap::new();
                o.insert("off".to_string(), Json::Str(hex16(c.off)));
                o.insert("len".to_string(), Json::Str(hex16(c.len)));
                o.insert("fnv".to_string(), Json::Str(hex16(c.fnv)));
                Json::Obj(o)
            })
            .collect();
        root.insert("chunks".to_string(), Json::Arr(chunks));
        Json::Obj(root)
    }

    /// Serialize to the full container byte stream. Returns
    /// `(bytes, content_hash)` — the hash is FNV-1a/64 over the
    /// manifest bytes and is what eval scopes key on.
    pub fn to_bytes(&self) -> (Vec<u8>, u64) {
        let manifest = self.manifest().to_string().into_bytes();
        let content_hash = fnv1a(&manifest);
        let mut out = Vec::with_capacity(HEADER_LEN + manifest.len() + self.data.len() + 64);
        out.extend_from_slice(ARTIFACT_MAGIC.as_bytes());
        out.extend_from_slice(hex16(manifest.len() as u64).as_bytes());
        out.push(b'\n');
        debug_assert_eq!(out.len(), HEADER_LEN);
        out.extend_from_slice(&manifest);
        let pad = (CHUNK_ALIGN - (out.len() as u64 % CHUNK_ALIGN)) % CHUNK_ALIGN;
        out.resize(out.len() + pad as usize, 0u8);
        out.extend_from_slice(&self.data);
        (out, content_hash)
    }

    /// Write the container to `path` atomically (`.tmp` + rename, the
    /// `CacheStore::save` idiom). Returns the content hash.
    pub fn write_to(&self, path: &Path) -> Result<u64> {
        let (bytes, content_hash) = self.to_bytes();
        crate::util::write_atomic(path, &bytes)
            .with_context(|| format!("writing artifact {}", path.display()))?;
        Ok(content_hash)
    }
}

// -------------------------------------------------------------- reader --

/// One loaded tensor: its packed bits plus the hash of the f32 weights
/// it was packed from.
#[derive(Debug, Clone)]
pub struct ArtifactTensor {
    pub desc: TensorDesc,
    /// Shared so the interpreter reuses loaded tensors without copying.
    pub packed: Arc<PackedTensor>,
}

/// A fully loaded, fully validated artifact.
#[derive(Debug, Clone)]
pub struct ArtifactWeights {
    /// FNV-1a/64 over the manifest bytes (see module docs).
    pub content_hash: u64,
    pub model: String,
    pub spec: FormatSpec,
    /// Tensors by name.
    pub tensors: BTreeMap<String, ArtifactTensor>,
}

impl ArtifactWeights {
    /// Open + stream-load + validate every tensor of an artifact.
    pub fn load(path: &Path) -> Result<ArtifactWeights> {
        ArtifactReader::open(path)?.load_all()
    }
}

struct ReaderTensor {
    desc: TensorDesc,
    exps_chunk: Option<usize>,
    words_chunk: usize,
}

/// Streaming `.mxa` loader: `open` reads and validates only the header +
/// manifest; each tensor's chunks are then read chunk-at-a-time with the
/// FNV hash updated incrementally as sub-buffers arrive, so corruption
/// is detected on first contact and memory peaks at one chunk.
pub struct ArtifactReader {
    file: std::fs::File,
    file_len: u64,
    /// Absolute file offset of chunk offset 0.
    data_base: u64,
    content_hash: u64,
    model: String,
    spec: FormatSpec,
    tensors: Vec<ReaderTensor>,
    chunks: Vec<ChunkRef>,
}

impl ArtifactReader {
    /// Open an artifact: validate magic, version, schema, manifest
    /// structure and chunk-table bounds. No chunk data is read yet.
    pub fn open(path: &Path) -> Result<ArtifactReader> {
        let mut file = std::fs::File::open(path)
            .with_context(|| format!("opening artifact {}", path.display()))?;
        let file_len = file.metadata()?.len();
        let mut header = [0u8; HEADER_LEN];
        file.read_exact(&mut header)
            .map_err(|_| anyhow!("truncated artifact: no {HEADER_LEN}-byte header"))?;
        let header =
            std::str::from_utf8(&header).map_err(|_| anyhow!("artifact header is not UTF-8"))?;
        ensure!(
            header.starts_with(ARTIFACT_MAGIC) && header.ends_with('\n'),
            "bad artifact magic (not an .mxa file, or an unsupported container version)"
        );
        let manifest_len = hex_u64(&header[ARTIFACT_MAGIC.len()..HEADER_LEN - 1])
            .ok_or_else(|| anyhow!("bad artifact header: malformed manifest length"))?;
        ensure!(
            HEADER_LEN as u64 + manifest_len <= file_len,
            "truncated artifact: manifest claims {manifest_len} bytes, file has {} after the header",
            file_len - HEADER_LEN as u64
        );
        let mut manifest = vec![0u8; manifest_len as usize];
        file.read_exact(&mut manifest)?;
        let content_hash = fnv1a(&manifest);
        let manifest = std::str::from_utf8(&manifest)
            .map_err(|_| anyhow!("artifact manifest is not UTF-8"))?;
        let root = Json::parse(manifest).map_err(|e| anyhow!("unreadable manifest: {e}"))?;

        match root.get("schema").and_then(Json::as_str) {
            Some(ARTIFACT_SCHEMA) => {}
            other => bail!("artifact schema {other:?} is not {ARTIFACT_SCHEMA:?}"),
        }
        let version = root.get("version").and_then(Json::as_str).and_then(hex_u64);
        ensure!(
            version == Some(ARTIFACT_VERSION),
            "artifact version {version:?} (this build reads {ARTIFACT_VERSION}) — refusing to \
             guess at the layout of a different version"
        );
        let model = root
            .get("model")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("manifest missing model"))?
            .to_string();
        let spec = {
            let f = root.get("format").ok_or_else(|| anyhow!("manifest missing format"))?;
            let kind_name = f
                .get("kind")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("manifest format: missing kind"))?;
            let kind = FormatKind::from_name(kind_name)
                .ok_or_else(|| anyhow!("manifest format: unknown kind {kind_name:?}"))?;
            let knob = |k: &str| -> Result<f32> {
                let bits = f
                    .get(k)
                    .and_then(Json::as_str)
                    .and_then(hex_u64)
                    .ok_or_else(|| anyhow!("manifest format: bad or missing {k:?}"))?;
                Ok(f64::from_bits(bits) as f32)
            };
            FormatSpec::new(kind, knob("bits")?, knob("frac")?)
        };

        let chunk_arr = root
            .get("chunks")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing chunks array"))?;
        let data_base = (HEADER_LEN as u64 + manifest_len).div_ceil(CHUNK_ALIGN) * CHUNK_ALIGN;
        let mut chunks = Vec::with_capacity(chunk_arr.len());
        for (i, c) in chunk_arr.iter().enumerate() {
            let f = |k: &str| -> Result<u64> {
                c.get(k)
                    .and_then(Json::as_str)
                    .and_then(hex_u64)
                    .ok_or_else(|| anyhow!("chunk {i}: bad or missing field {k:?}"))
            };
            let (off, len, fnv) = (f("off")?, f("len")?, f("fnv")?);
            ensure!(off % CHUNK_ALIGN == 0, "chunk {i}: offset {off} is not 64-byte aligned");
            let end = data_base
                .checked_add(off)
                .and_then(|s| s.checked_add(len))
                .ok_or_else(|| anyhow!("chunk {i}: offset overflow"))?;
            ensure!(
                end <= file_len,
                "truncated artifact: chunk {i} ends at byte {end}, file has {file_len}"
            );
            chunks.push(ChunkRef { off, len, fnv });
        }

        let tensor_arr = root
            .get("tensors")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing tensors array"))?;
        let mut tensors: Vec<ReaderTensor> = Vec::with_capacity(tensor_arr.len());
        for t in tensor_arr {
            let desc = TensorDesc::from_json(t)?;
            let name = desc.name.clone();
            ensure!(
                tensors.iter().all(|e| e.desc.name != name),
                "duplicate tensor {name:?} in manifest"
            );
            let chunk_ix = |k: &str| -> Result<usize> {
                let i = t
                    .get(k)
                    .and_then(Json::as_str)
                    .and_then(hex_u64)
                    .ok_or_else(|| anyhow!("tensor {name:?}: bad or missing {k:?}"))?
                    as usize;
                ensure!(i < chunks.len(), "tensor {name:?}: {k} {i} out of chunk-table bounds");
                Ok(i)
            };
            let exps_chunk = if desc.layout.fmt.is_block_format() {
                ensure!(
                    desc.rows % BLOCK_SHAPE.0 == 0 && desc.cols % BLOCK_SHAPE.1 == 0,
                    "tensor {name:?}: {}x{} does not tile into (16, 2) blocks",
                    desc.rows,
                    desc.cols
                );
                Some(chunk_ix("exps_chunk")?)
            } else {
                ensure!(
                    t.get("exps_chunk").is_none(),
                    "tensor {name:?}: element-wise layout with an exps chunk"
                );
                None
            };
            let words_chunk = chunk_ix("words_chunk")?;
            // Reject wrong-sized chunks up front, against the layout's
            // own sizing equations.
            let (want_exps, want_words) = expected_sizes(&desc.layout, desc.rows, desc.cols);
            if let Some(e) = exps_chunk {
                ensure!(
                    chunks[e].len == want_exps as u64,
                    "tensor {name:?}: exps chunk holds {} bytes, layout demands {want_exps}",
                    chunks[e].len
                );
            }
            ensure!(
                chunks[words_chunk].len == want_words as u64 * 8,
                "tensor {name:?}: words chunk holds {} bytes, layout demands {}",
                chunks[words_chunk].len,
                want_words * 8
            );
            tensors.push(ReaderTensor { desc, exps_chunk, words_chunk });
        }

        Ok(ArtifactReader {
            file,
            file_len,
            data_base,
            content_hash,
            model,
            spec,
            tensors,
            chunks,
        })
    }

    /// FNV-1a/64 over the manifest bytes.
    pub fn content_hash(&self) -> u64 {
        self.content_hash
    }

    pub fn model(&self) -> &str {
        &self.model
    }

    pub fn spec(&self) -> FormatSpec {
        self.spec
    }

    /// Tensor descriptors, in on-disk order.
    pub fn descriptors(&self) -> impl Iterator<Item = &TensorDesc> {
        self.tensors.iter().map(|t| &t.desc)
    }

    /// Read one chunk streaming (64 KiB sub-buffers), updating the FNV
    /// hash as bytes arrive and failing closed on any mismatch.
    fn read_chunk(&mut self, ix: usize, owner: &str) -> Result<Vec<u8>> {
        use std::io::{Seek, SeekFrom};
        let c = &self.chunks[ix];
        let (off, len, want) = (self.data_base + c.off, c.len, c.fnv);
        ensure!(
            off + len <= self.file_len,
            "truncated artifact: chunk {ix} (tensor {owner:?}) ends past EOF"
        );
        self.file.seek(SeekFrom::Start(off))?;
        let mut out = Vec::with_capacity(len as usize);
        let mut hash = Fnv1a::new();
        let mut buf = [0u8; 64 * 1024];
        let mut left = len as usize;
        while left > 0 {
            let take = left.min(buf.len());
            self.file.read_exact(&mut buf[..take]).map_err(|_| {
                anyhow!("truncated artifact: chunk {ix} (tensor {owner:?}) cut short")
            })?;
            hash.update(&buf[..take]);
            out.extend_from_slice(&buf[..take]);
            left -= take;
        }
        ensure!(
            hash.finish() == want,
            "corrupt artifact: chunk {ix} (tensor {owner:?}) hash {:016x} != manifest {want:016x}",
            hash.finish()
        );
        Ok(out)
    }

    /// Load + validate the `i`-th tensor (on-disk order).
    fn load_ix(&mut self, i: usize) -> Result<(TensorDesc, PackedTensor)> {
        let (desc, exps_chunk, words_chunk) = {
            let t = &self.tensors[i];
            (t.desc.clone(), t.exps_chunk, t.words_chunk)
        };
        let exps = match exps_chunk {
            Some(e) => self.read_chunk(e, &desc.name)?,
            None => Vec::new(),
        };
        let word_bytes = self.read_chunk(words_chunk, &desc.name)?;
        let words: Vec<u64> = word_bytes
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
            .collect();
        let packed =
            PackedTensor { layout: desc.layout, rows: desc.rows, cols: desc.cols, exps, words };
        Ok((desc, packed))
    }

    /// Stream-load every tensor, consuming the reader.
    pub fn load_all(mut self) -> Result<ArtifactWeights> {
        let mut tensors = BTreeMap::new();
        for i in 0..self.tensors.len() {
            let (desc, packed) = self.load_ix(i)?;
            tensors
                .insert(desc.name.clone(), ArtifactTensor { desc, packed: Arc::new(packed) });
        }
        Ok(ArtifactWeights {
            content_hash: self.content_hash,
            model: self.model,
            spec: self.spec,
            tensors,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::packed::layout::pack;
    use crate::util::rng::Rng;

    fn tmp_path(tag: &str) -> std::path::PathBuf {
        use std::sync::atomic::{AtomicUsize, Ordering};
        static UNIQUE: AtomicUsize = AtomicUsize::new(0);
        let n = UNIQUE.fetch_add(1, Ordering::Relaxed);
        std::env::temp_dir().join(format!("mase_mxa_{tag}_{}_{n}.mxa", std::process::id()))
    }

    fn rand_tensor(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn fnv1a_matches_reference_vectors() {
        // Published FNV-1a/64 test vectors.
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a(b"foobar"), 0x85944171f73967e8);
        // incremental == one-shot
        let mut h = Fnv1a::new();
        h.update(b"foo");
        h.update(b"bar");
        assert_eq!(h.finish(), fnv1a(b"foobar"));
    }

    #[test]
    fn round_trip_preserves_every_field() {
        let spec = FormatSpec::new(FormatKind::MxInt, 6.0, 0.0);
        let x = rand_tensor(32 * 4, 7);
        let t = pack(&x, 32, 4, spec.kind, spec.precision());
        let mut w = ArtifactWriter::new("m", spec);
        w.add_tensor(TensorDesc::for_tensor("layer0.w", "weight", &t, &x), &t).unwrap();
        let path = tmp_path("rt");
        let hash = w.write_to(&path).unwrap();

        let loaded = ArtifactWeights::load(&path).unwrap();
        assert_eq!(loaded.content_hash, hash);
        assert_eq!(loaded.model, "m");
        assert_eq!(loaded.spec, spec);
        let lt = &loaded.tensors["layer0.w"];
        assert_eq!(lt.desc.source_hash, source_hash(&x));
        assert_eq!(*lt.packed, t, "packed bits must survive byte-for-byte");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn header_is_fixed_width_and_data_is_aligned() {
        let spec = FormatSpec::with_defaults(FormatKind::Int);
        let x = rand_tensor(33, 3); // partial trailing group
        let t = pack(&x, 3, 11, spec.kind, spec.precision());
        let mut w = ArtifactWriter::new("m", spec);
        w.add_tensor(TensorDesc::for_tensor("w", "weight", &t, &x), &t).unwrap();
        let (bytes, hash) = w.to_bytes();
        assert_eq!(&bytes[..5], b"MXA1 ");
        assert_eq!(bytes[HEADER_LEN - 1], b'\n');
        let mlen = hex_u64(std::str::from_utf8(&bytes[5..21]).unwrap()).unwrap() as usize;
        assert_eq!(fnv1a(&bytes[HEADER_LEN..HEADER_LEN + mlen]), hash);
        let base = (HEADER_LEN + mlen).div_ceil(64) * 64;
        assert!(bytes.len() > base);
        assert_eq!(bytes[HEADER_LEN + mlen..base].iter().filter(|&&b| b != 0).count(), 0);
    }

    #[test]
    fn zero_element_tensor_round_trips() {
        let spec = FormatSpec::with_defaults(FormatKind::Fp8);
        let t = pack(&[], 0, 7, spec.kind, spec.precision());
        let mut w = ArtifactWriter::new("m", spec);
        w.add_tensor(TensorDesc::for_tensor("empty", "weight", &t, &[]), &t).unwrap();
        let path = tmp_path("empty");
        w.write_to(&path).unwrap();
        let loaded = ArtifactWeights::load(&path).unwrap();
        assert_eq!(loaded.tensors["empty"].packed.unpack(), Vec::<f32>::new());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn flipped_chunk_byte_fails_closed_naming_the_tensor() {
        let spec = FormatSpec::new(FormatKind::Bmf, 5.0, 0.0);
        let x = rand_tensor(32 * 2, 11);
        let t = pack(&x, 32, 2, spec.kind, spec.precision());
        let mut w = ArtifactWriter::new("m", spec);
        w.add_tensor(TensorDesc::for_tensor("layer3.fc1", "weight", &t, &x), &t).unwrap();
        let (mut bytes, _) = w.to_bytes();
        let last = bytes.len() - 1;
        bytes[last] ^= 0x40; // inside the final (words) chunk
        let path = tmp_path("flip");
        std::fs::write(&path, &bytes).unwrap();
        let err = ArtifactWeights::load(&path).unwrap_err().to_string();
        assert!(err.contains("layer3.fc1"), "error must name the tensor: {err}");
        assert!(err.contains("hash"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn truncation_fails_closed() {
        let spec = FormatSpec::new(FormatKind::MxInt, 7.0, 0.0);
        let x = rand_tensor(32 * 2, 13);
        let t = pack(&x, 32, 2, spec.kind, spec.precision());
        let mut w = ArtifactWriter::new("m", spec);
        w.add_tensor(TensorDesc::for_tensor("w", "weight", &t, &x), &t).unwrap();
        let (bytes, _) = w.to_bytes();
        let path = tmp_path("trunc");
        // cut mid-way through the chunk data
        std::fs::write(&path, &bytes[..bytes.len() - 16]).unwrap();
        let err = ArtifactWeights::load(&path).unwrap_err().to_string();
        assert!(err.contains("truncated"), "{err}");
        // cut inside the manifest
        std::fs::write(&path, &bytes[..HEADER_LEN + 4]).unwrap();
        assert!(ArtifactReader::open(&path).is_err());
        // cut inside the header
        std::fs::write(&path, &bytes[..10]).unwrap();
        let err = ArtifactReader::open(&path).unwrap_err().to_string();
        assert!(err.contains("header"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn version_bump_is_refused() {
        let spec = FormatSpec::with_defaults(FormatKind::MxInt);
        let x = rand_tensor(32 * 2, 17);
        let t = pack(&x, 32, 2, spec.kind, spec.precision());
        let mut w = ArtifactWriter::new("m", spec);
        w.add_tensor(TensorDesc::for_tensor("w", "weight", &t, &x), &t).unwrap();
        let (mut bytes, _) = w.to_bytes();
        let old = format!("\"version\":\"{}\"", hex16(ARTIFACT_VERSION));
        let new = format!("\"version\":\"{}\"", hex16(ARTIFACT_VERSION + 1));
        // same-length in-place patch keeps the header length honest
        let pos = bytes
            .windows(old.len())
            .position(|w| w == old.as_bytes())
            .expect("manifest carries the version field");
        bytes[pos..pos + old.len()].copy_from_slice(new.as_bytes());
        let path = tmp_path("ver");
        std::fs::write(&path, &bytes).unwrap();
        let err = ArtifactReader::open(&path).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn source_hash_is_order_and_bit_sensitive() {
        let a = source_hash(&[1.0, 2.0]);
        assert_ne!(a, source_hash(&[2.0, 1.0]));
        assert_ne!(a, source_hash(&[1.0, 2.0, 0.0]));
        assert_ne!(source_hash(&[0.0]), source_hash(&[-0.0]), "bit pattern, not value");
        assert_eq!(a, source_hash(&[1.0, 2.0]));
    }
}
