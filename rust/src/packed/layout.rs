//! Per-format packed block encodings: shared 8-bit exponent + bit-packed
//! mantissa words, with explicit padding/alignment rules.
//!
//! ## Storage layout (on disk and in memory)
//!
//! Elements are grouped 32 at a time. For the block formats (MXInt, BMF,
//! BL) a group is one (16, 2) tile of the row-major 2-D tensor in the
//! same order the quantizers visit it (`formats::for_each_block`, element
//! index inside the block = `r * 2 + c`), and each group carries one
//! shared exponent byte (stored biased: `e - SHARED_EXP_MIN`, so the
//! 8-bit field covers the clamp range [-126, 127]). For the element-wise
//! formats (fixed point, minifloat/FP8, fp32) a group is 32 consecutive
//! elements in flat row-major order, with no exponent byte, and the last
//! group may be partial.
//!
//! Element fields are packed LSB-first into little-endian `u64` words.
//! **Alignment rule:** every group starts on a fresh `u64` word, so an
//! element's word/bit address is computable in O(1) from its coordinates
//! (the property the hardware's streaming readers rely on). The padding
//! this costs is `words_per_group * 64 - 32 * elem_bits` bits per full
//! group — zero whenever `elem_bits` is a power-of-two divisor of 64,
//! 32 bits per block for odd `elem_bits`.
//!
//! ## Element encodings
//!
//! | format | field layout (MSB..LSB) | bits |
//! |---|---|---|
//! | MXInt | sign, m-bit magnitude | 1 + m |
//! | BMF | sign, 2-bit local exp code, (m+1)-bit magnitude | 1 + 2 + m + 1 |
//! | BL | sign, (eb+1)-bit exponent index (code 0 = zero) | 1 + eb + 1 |
//! | fixed | w-bit two's complement | w |
//! | FP8 | sign, 4-bit exponent code (0 = zero/denormal), 3-bit fraction | 8 |
//! | fp32 | raw IEEE-754 bits | 32 |
//!
//! Two fields are wider than the idealized Eq. (1) accounting, on
//! purpose: the fake-quantized **BMF** grid keeps both denormal and
//! normalized values in its bottom binade, which needs one extra
//! magnitude bit (`k <= 2^(m+1) - 1`); and the **BL** grid keeps exact
//! signed zeros next to `2^eb` exponent levels, which needs a zero code
//! on top of the eb-bit exponent. A true hardware BMF/BL would drop
//! those values from the grid; the packed layout stores the *software
//! reference grid* exactly and reports the honest measured bytes, which
//! the benches print next to the analytic density so the gap is visible.
//!
//! Decoding recomputes values with the same exact primitives the
//! quantizers use (`formats::pow2`, integer-times-power-of-two f32
//! multiplies), so `unpack(pack(x))` is bit-identical to
//! `formats::*_quantize(x)` — the round-trip property the tests enforce.

use crate::formats::{
    self, block_maxabs, bmf::LOCAL_EXP_BITS, floor_log2, for_each_block, pow2, shared_exponent,
    FormatKind, Precision, BLOCK_SHAPE, SHARED_EXPONENT_BITS, SHARED_EXP_MIN,
};

/// Elements per packed group: one (16, 2) block.
pub const GROUP_ELEMS: usize = BLOCK_SHAPE.0 * BLOCK_SHAPE.1;

/// FP8 (MiniFloat) constants — fixed at the paper's e4m3, bias 7.
const FP8_EXP_BITS: i32 = 4;
const FP8_MAN_BITS: i32 = 3;
const FP8_BIAS: i32 = 7;

#[inline]
fn mask(n: u32) -> u64 {
    if n >= 64 {
        u64::MAX
    } else {
        (1u64 << n) - 1
    }
}

/// Resolve a format's primary precision knob exactly as its quantizer
/// does (round, then clamp to the quantizer's floor).
fn resolve_knob(fmt: FormatKind, p: Precision) -> i32 {
    match fmt {
        FormatKind::Fp32 => 32,
        FormatKind::Fp8 => FP8_MAN_BITS,
        FormatKind::Int => p.bits.round().max(2.0) as i32,
        FormatKind::MxInt | FormatKind::Bmf | FormatKind::Bl => p.bits.round().max(1.0) as i32,
    }
}

/// Widest knob each format can pack with exact f32 round trips (mantissa
/// products and scales stay exactly representable; see module docs).
fn max_knob(fmt: FormatKind) -> i32 {
    match fmt {
        FormatKind::Fp32 => 32,
        FormatKind::Fp8 => FP8_MAN_BITS,
        FormatKind::Int => 25,
        FormatKind::MxInt => 24,
        FormatKind::Bmf => 23,
        FormatKind::Bl => 16,
    }
}

/// Resolved per-element field layout for one (format, precision) pair.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ElemLayout {
    pub fmt: FormatKind,
    /// Resolved integer knob: mantissa bits (MXInt/BMF), element exponent
    /// bits (BL), total width (fixed), 3 (FP8), 32 (fp32). Clamped to
    /// the packable range; `pack` asserts no clamping actually occurred.
    pub knob: i32,
    /// Fraction bits (fixed point only).
    pub frac: i32,
    /// Total bits of one packed element field.
    pub elem_bits: u32,
    /// Bits of the per-group shared exponent (8 for block formats, 0
    /// otherwise).
    pub shared_exp_bits: u32,
}

impl ElemLayout {
    pub fn new(fmt: FormatKind, p: Precision) -> ElemLayout {
        let knob = resolve_knob(fmt, p).min(max_knob(fmt));
        let frac = if fmt == FormatKind::Int { p.frac.round() as i32 } else { 0 };
        let elem_bits = match fmt {
            FormatKind::Fp32 => 32,
            FormatKind::Fp8 => (1 + FP8_EXP_BITS + FP8_MAN_BITS) as u32,
            FormatKind::Int => knob as u32,
            FormatKind::MxInt => 1 + knob as u32,
            FormatKind::Bmf => 1 + LOCAL_EXP_BITS + knob as u32 + 1,
            FormatKind::Bl => 1 + knob as u32 + 1,
        };
        let shared_exp_bits = if fmt.is_block_format() { SHARED_EXPONENT_BITS } else { 0 };
        ElemLayout { fmt, knob, frac, elem_bits, shared_exp_bits }
    }

    /// `u64` words holding `n` packed elements (groups are word-aligned).
    pub fn words_per_group(&self, n: usize) -> usize {
        (n * self.elem_bits as usize).div_ceil(64)
    }

    /// Padding bits a full 32-element group carries for word alignment.
    pub fn padding_bits_per_group(&self) -> u32 {
        self.words_per_group(GROUP_ELEMS) as u32 * 64 - GROUP_ELEMS as u32 * self.elem_bits
    }

    fn bmf_e_min(&self) -> i32 {
        -(pow2(LOCAL_EXP_BITS as i32) as i32 - 1)
    }

    fn bl_e_min(&self, bias: i32) -> i32 {
        bias - (pow2(self.knob) as i32 - 1)
    }

    /// Encode one on-grid value into its element field. `e_block` is the
    /// group's shared exponent (ignored by element-wise formats). `v`
    /// must lie on the fake-quantized grid of this layout.
    pub fn encode(&self, v: f32, e_block: i32) -> u64 {
        let sign = v.is_sign_negative() as u64;
        match self.fmt {
            FormatKind::Fp32 => v.to_bits() as u64,
            FormatKind::Int => {
                let k = (v / pow2(-self.frac)) as i64;
                debug_assert_eq!((k as f32) * pow2(-self.frac), v, "off-grid fixed value {v}");
                (k as u64) & mask(self.elem_bits)
            }
            FormatKind::Fp8 => {
                if v == 0.0 {
                    return sign << 7;
                }
                let a = v.abs();
                let unb = floor_log2(a);
                let e_min = 1 - FP8_BIAS;
                if unb < e_min {
                    // Denormal binade of the grid: the quantizer's clamp
                    // rounds [2^(e_min-1), 2^e_min) inputs to
                    // k * 2^(e_min - m), k in [1, 2^m) — IEEE-style
                    // exponent code 0, no hidden bit.
                    let q = a / pow2(e_min - FP8_MAN_BITS);
                    let t = q as u64;
                    debug_assert!(
                        q.fract() == 0.0 && t >= 1 && t < 1 << FP8_MAN_BITS,
                        "off-grid fp8 denormal {v}"
                    );
                    return sign << 7 | t;
                }
                let t = ((a.to_bits() >> (23 - FP8_MAN_BITS)) & 0x7) as u64;
                debug_assert_eq!(
                    a.to_bits() & ((1 << (23 - FP8_MAN_BITS)) - 1),
                    0,
                    "off-grid fp8 {v}"
                );
                sign << 7 | ((unb + FP8_BIAS) as u64) << FP8_MAN_BITS | t
            }
            FormatKind::MxInt => {
                let m = self.knob;
                let q = v / pow2(e_block + 1 - m);
                let magn = q.abs() as u64;
                debug_assert!(
                    q.abs().fract() == 0.0 && magn <= mask(m as u32),
                    "off-grid mxint value {v} (e={e_block}, m={m})"
                );
                sign << m | magn
            }
            FormatKind::Bmf => {
                let m = self.knob;
                if v == 0.0 {
                    return sign << (LOCAL_EXP_BITS + m as u32 + 1);
                }
                let e_min = self.bmf_e_min();
                let a = v.abs();
                let e_loc = (floor_log2(a) - e_block).clamp(e_min, 0);
                let q = a / pow2(e_loc + e_block - m);
                let k = q as u64;
                debug_assert!(
                    q.fract() == 0.0 && k >= 1 && k <= mask(m as u32 + 1),
                    "off-grid bmf value {v} (bias={e_block}, m={m})"
                );
                sign << (LOCAL_EXP_BITS + m as u32 + 1)
                    | ((e_loc - e_min) as u64) << (m as u32 + 1)
                    | k
            }
            FormatKind::Bl => {
                if v == 0.0 {
                    return sign << (self.knob as u32 + 1);
                }
                let e_min = self.bl_e_min(e_block);
                let c = (floor_log2(v.abs()) - e_min + 1) as u64;
                debug_assert!(
                    c >= 1 && c <= 1 << self.knob,
                    "off-grid bl value {v} (bias={e_block}, eb={})",
                    self.knob
                );
                sign << (self.knob as u32 + 1) | c
            }
        }
    }

    /// Decode one element field back to the exact f32 grid value.
    pub fn decode(&self, code: u64, e_block: i32) -> f32 {
        let signed = |sign: u64, a: f32| if sign == 1 { -a } else { a };
        match self.fmt {
            FormatKind::Fp32 => f32::from_bits(code as u32),
            FormatKind::Int => {
                let w = self.elem_bits;
                let k = (((code & mask(w)) << (64 - w)) as i64) >> (64 - w);
                (k as f32) * pow2(-self.frac)
            }
            FormatKind::Fp8 => {
                let sign = (code >> 7) & 1;
                let ec = (code >> FP8_MAN_BITS) & mask(FP8_EXP_BITS as u32);
                let t = code & mask(FP8_MAN_BITS as u32);
                if ec == 0 {
                    if t == 0 {
                        return signed(sign, 0.0);
                    }
                    // denormal: no hidden bit, exponent pinned at e_min
                    return signed(sign, t as f32 * pow2(1 - FP8_BIAS - FP8_MAN_BITS));
                }
                let unb = ec as i32 - FP8_BIAS;
                signed(sign, ((1 << FP8_MAN_BITS) + t) as f32 * pow2(unb - FP8_MAN_BITS))
            }
            FormatKind::MxInt => {
                let m = self.knob;
                let magn = (code & mask(m as u32)) as f32;
                signed((code >> m) & 1, magn * pow2(e_block + 1 - m))
            }
            FormatKind::Bmf => {
                let m = self.knob;
                let sign = (code >> (LOCAL_EXP_BITS + m as u32 + 1)) & 1;
                let k = code & mask(m as u32 + 1);
                if k == 0 {
                    return signed(sign, 0.0);
                }
                let ec = (code >> (m as u32 + 1)) & mask(LOCAL_EXP_BITS);
                let e_loc = self.bmf_e_min() + ec as i32;
                signed(sign, k as f32 * pow2(e_loc + e_block - m))
            }
            FormatKind::Bl => {
                let sign = (code >> (self.knob as u32 + 1)) & 1;
                let c = code & mask(self.knob as u32 + 1);
                if c == 0 {
                    return signed(sign, 0.0);
                }
                signed(sign, pow2(self.bl_e_min(e_block) + c as i32 - 1))
            }
        }
    }

    /// Exact integer decomposition of an element: `value == mant * 2^exp`
    /// as real numbers, with `mant` an integer (|mant| < 2^26 for every
    /// supported layout). This is what the integer-datapath kernels
    /// consume without materializing f32s.
    pub fn fields(&self, code: u64, e_block: i32) -> (i64, i32) {
        // Mirror pow2's exponent clamp so mant * 2^exp equals the f32
        // value produced by `decode` exactly, subnormal corners included.
        let clamp = |e: i32| e.clamp(-149, 127);
        let signed = |sign: u64, m: i64| if sign == 1 { -m } else { m };
        match self.fmt {
            FormatKind::Fp32 => {
                let bits = code as u32;
                let sign = (bits >> 31) as u64;
                let ef = (bits >> 23) & 0xff;
                let fr = (bits & 0x7f_ffff) as i64;
                if ef == 0 {
                    (signed(sign, fr), -149)
                } else {
                    (signed(sign, fr | 0x80_0000), ef as i32 - 127 - 23)
                }
            }
            FormatKind::Int => {
                let w = self.elem_bits;
                let k = (((code & mask(w)) << (64 - w)) as i64) >> (64 - w);
                (k, clamp(-self.frac))
            }
            FormatKind::Fp8 => {
                let sign = (code >> 7) & 1;
                let ec = (code >> FP8_MAN_BITS) & mask(FP8_EXP_BITS as u32);
                let t = (code & mask(FP8_MAN_BITS as u32)) as i64;
                if ec == 0 {
                    if t == 0 {
                        return (0, 0);
                    }
                    return (signed(sign, t), 1 - FP8_BIAS - FP8_MAN_BITS);
                }
                (signed(sign, (1 << FP8_MAN_BITS) + t), ec as i32 - FP8_BIAS - FP8_MAN_BITS)
            }
            FormatKind::MxInt => {
                let m = self.knob;
                let magn = (code & mask(m as u32)) as i64;
                (signed((code >> m) & 1, magn), clamp(e_block + 1 - m))
            }
            FormatKind::Bmf => {
                let m = self.knob;
                let sign = (code >> (LOCAL_EXP_BITS + m as u32 + 1)) & 1;
                let k = (code & mask(m as u32 + 1)) as i64;
                if k == 0 {
                    return (0, 0);
                }
                let ec = (code >> (m as u32 + 1)) & mask(LOCAL_EXP_BITS);
                (signed(sign, k), clamp(self.bmf_e_min() + ec as i32 + e_block - m))
            }
            FormatKind::Bl => {
                let sign = (code >> (self.knob as u32 + 1)) & 1;
                let c = code & mask(self.knob as u32 + 1);
                if c == 0 {
                    return (0, 0);
                }
                (signed(sign, 1), clamp(self.bl_e_min(e_block) + c as i32 - 1))
            }
        }
    }
}

fn write_bits(words: &mut [u64], bit: usize, n: u32, val: u64) {
    debug_assert!((n < 64 && val <= mask(n)) || n == 64);
    let w = bit / 64;
    let off = (bit % 64) as u32;
    words[w] |= val << off;
    if off + n > 64 {
        words[w + 1] |= val >> (64 - off);
    }
}

fn read_bits(words: &[u64], bit: usize, n: u32) -> u64 {
    let w = bit / 64;
    let off = (bit % 64) as u32;
    let mut v = words[w] >> off;
    if off + n > 64 {
        v |= words[w + 1] << (64 - off);
    }
    v & mask(n)
}

/// A bit-packed 2-D tensor: the storage format of module docs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PackedTensor {
    pub layout: ElemLayout,
    pub rows: usize,
    pub cols: usize,
    /// One biased shared-exponent byte per (16, 2) block (block formats
    /// only, in `for_each_block` order).
    pub exps: Vec<u8>,
    /// Bit-packed element fields; each group starts on a fresh word.
    pub words: Vec<u64>,
}

/// Quantize (via the official `formats` quantizers) and pack a row-major
/// 2-D tensor. Block formats require `rows % 16 == 0 && cols % 2 == 0`
/// (the same constraint the quantizers assert); element-wise formats
/// accept any shape and pad only the trailing partial group.
pub fn pack(data: &[f32], rows: usize, cols: usize, fmt: FormatKind, p: Precision) -> PackedTensor {
    assert_eq!(data.len(), rows * cols, "data length vs shape");
    let lay = ElemLayout::new(fmt, p);
    assert_eq!(
        lay.knob,
        resolve_knob(fmt, p),
        "precision {} exceeds the packable range of {} (max knob {})",
        p.bits,
        fmt.name(),
        max_knob(fmt)
    );
    let mut q = data.to_vec();
    formats::quantize_2d(fmt, &mut q, rows, cols, p);

    let mut t = PackedTensor { layout: lay, rows, cols, exps: Vec::new(), words: Vec::new() };
    let eb = lay.elem_bits as usize;
    if fmt.is_block_format() {
        let (br, bc) = BLOCK_SHAPE;
        let wpb = lay.words_per_group(GROUP_ELEMS);
        t.words = vec![0u64; (rows / br) * (cols / bc) * wpb];
        let mut bi = 0usize;
        for_each_block(rows, cols, |start| {
            // The shared exponent is derived from the *original* block,
            // exactly as the quantizer derived it (quantization preserves
            // the block's floor(log2 max|x|), so either source agrees).
            let e = shared_exponent(block_maxabs(data, start, cols));
            t.exps.push((e - SHARED_EXP_MIN) as u8);
            let base = bi * wpb * 64;
            for r in 0..br {
                for c in 0..bc {
                    let code = lay.encode(q[start + r * cols + c], e);
                    write_bits(&mut t.words, base + (r * bc + c) * eb, lay.elem_bits, code);
                }
            }
            bi += 1;
        });
    } else {
        let n = q.len();
        let wpg = lay.words_per_group(GROUP_ELEMS);
        let rem = n % GROUP_ELEMS;
        let nwords =
            (n / GROUP_ELEMS) * wpg + if rem > 0 { lay.words_per_group(rem) } else { 0 };
        t.words = vec![0u64; nwords];
        for (i, &v) in q.iter().enumerate() {
            let base = (i / GROUP_ELEMS) * wpg * 64;
            write_bits(&mut t.words, base + (i % GROUP_ELEMS) * eb, lay.elem_bits, lay.encode(v, 0));
        }
    }
    t
}

impl PackedTensor {
    fn block_addr(&self, r: usize, c: usize) -> (usize, i32) {
        let (br, bc) = BLOCK_SHAPE;
        let eb = self.layout.elem_bits as usize;
        if self.layout.fmt.is_block_format() {
            let bi = (r / br) * (self.cols / bc) + c / bc;
            let j = (r % br) * bc + c % bc;
            let wpb = self.layout.words_per_group(GROUP_ELEMS);
            (bi * wpb * 64 + j * eb, self.exps[bi] as i32 + SHARED_EXP_MIN)
        } else {
            let i = r * self.cols + c;
            let wpg = self.layout.words_per_group(GROUP_ELEMS);
            ((i / GROUP_ELEMS) * wpg * 64 + (i % GROUP_ELEMS) * eb, 0)
        }
    }

    /// Decode the element at (row, col) back to its exact f32 value.
    pub fn get(&self, r: usize, c: usize) -> f32 {
        let (bit, e) = self.block_addr(r, c);
        self.layout.decode(read_bits(&self.words, bit, self.layout.elem_bits), e)
    }

    /// Exact `(mantissa, exponent)` decomposition of the element at
    /// (row, col) — see [`ElemLayout::fields`]. O(1) random access, which
    /// is what the group word-alignment rule buys.
    pub fn fields_at(&self, r: usize, c: usize) -> (i64, i32) {
        let (bit, e) = self.block_addr(r, c);
        self.layout.fields(read_bits(&self.words, bit, self.layout.elem_bits), e)
    }

    /// Unpack to a row-major f32 tensor — bit-identical to the
    /// fake-quantized tensor `pack` consumed (module docs, contract 1).
    pub fn unpack(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.rows * self.cols];
        for r in 0..self.rows {
            for c in 0..self.cols {
                out[r * self.cols + c] = self.get(r, c);
            }
        }
        out
    }

    /// Total storage including shared exponents and alignment padding.
    pub fn storage_bits(&self) -> u64 {
        self.words.len() as u64 * 64 + self.exps.len() as u64 * 8
    }

    /// Measured average bits per element (the honest counterpart of
    /// `Precision::average_bitwidth`).
    pub fn avg_bits_per_elem(&self) -> f64 {
        let n = self.rows * self.cols;
        if n == 0 {
            0.0
        } else {
            self.storage_bits() as f64 / n as f64
        }
    }
}

/// Measured packed storage (bits) for a tensor of `shape` under
/// (`fmt`, `p`), without materializing any data. Matches
/// `pack(..).storage_bits()` exactly for packable shapes; shapes that do
/// not tile into (16, 2) blocks are priced with partial blocks padded to
/// full ones (the padding rule streaming hardware applies). This is the
/// number `hw::memory` budgets with.
pub fn packed_bits_for(fmt: FormatKind, p: Precision, shape: &[usize]) -> u64 {
    let lay = ElemLayout::new(fmt, p);
    let n: usize = shape.iter().product();
    if n == 0 {
        return 0;
    }
    if fmt.is_block_format() {
        let (br, bc) = BLOCK_SHAPE;
        let blocks = if shape.len() == 2 {
            shape[0].div_ceil(br) * shape[1].div_ceil(bc)
        } else {
            n.div_ceil(GROUP_ELEMS)
        };
        let per_block = lay.words_per_group(GROUP_ELEMS) as u64 * 64 + SHARED_EXPONENT_BITS as u64;
        blocks as u64 * per_block
    } else {
        let rem = n % GROUP_ELEMS;
        let words = (n / GROUP_ELEMS) * lay.words_per_group(GROUP_ELEMS)
            + if rem > 0 { lay.words_per_group(rem) } else { 0 };
        words as u64 * 64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_tensor(n: usize, seed: u64, scale: f64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| (rng.normal() * scale) as f32).collect()
    }

    fn quantized(fmt: FormatKind, x: &[f32], rows: usize, cols: usize, p: Precision) -> Vec<f32> {
        let mut q = x.to_vec();
        formats::quantize_2d(fmt, &mut q, rows, cols, p);
        q
    }

    #[test]
    fn bit_rw_round_trips_across_word_boundaries() {
        let mut words = vec![0u64; 3];
        // 9-bit fields straddle the 64-bit boundary at element 7.
        for i in 0..14 {
            write_bits(&mut words, i * 9, 9, (i as u64 * 37) & 0x1ff);
        }
        for i in 0..14 {
            assert_eq!(read_bits(&words, i * 9, 9), (i as u64 * 37) & 0x1ff, "field {i}");
        }
    }

    #[test]
    fn mxint_round_trip_is_bit_exact() {
        for seed in 0..6 {
            let x = rand_tensor(32 * 8, seed, [1.0, 1e3, 1e-3][seed as usize % 3]);
            let p = Precision::new(5.0, 0.0);
            let t = pack(&x, 32, 8, FormatKind::MxInt, p);
            let q = quantized(FormatKind::MxInt, &x, 32, 8, p);
            for (i, (u, qv)) in t.unpack().iter().zip(q.iter()).enumerate() {
                assert_eq!(u.to_bits(), qv.to_bits(), "elem {i}: {u} vs {qv}");
            }
        }
    }

    #[test]
    fn signed_zeros_survive_the_round_trip() {
        // Small negatives round to -0.0 on the MXInt grid; the sign bit
        // must survive packing (sign-magnitude storage).
        let mut x = vec![1.0f32; 32];
        x[3] = -1e-6;
        x[5] = -0.0;
        let p = Precision::new(4.0, 0.0);
        let t = pack(&x, 16, 2, FormatKind::MxInt, p);
        let q = quantized(FormatKind::MxInt, &x, 16, 2, p);
        let u = t.unpack();
        assert!(q[3] == 0.0 && q[3].is_sign_negative(), "premise: -1e-6 rounds to -0.0");
        assert_eq!(u[3].to_bits(), q[3].to_bits());
        assert_eq!(u[5].to_bits(), q[5].to_bits());
    }

    #[test]
    fn all_zero_block_round_trips() {
        let x = vec![0.0f32; 64];
        for fmt in [FormatKind::MxInt, FormatKind::Bmf, FormatKind::Bl] {
            let t = pack(&x, 32, 2, fmt, Precision::new(4.0, 0.0));
            assert_eq!(t.exps, vec![0u8, 0u8], "{}: all-zero blocks store e_min", fmt.name());
            assert!(t.unpack().iter().all(|&v| v == 0.0));
        }
    }

    #[test]
    fn subnormal_heavy_blocks_round_trip_bit_exactly() {
        for fmt in [FormatKind::MxInt, FormatKind::Bmf, FormatKind::Bl] {
            let x = rand_tensor(32 * 4, 11, 1e-41); // mostly f32 subnormals
            assert!(x.iter().any(|v| v.abs() > 0.0 && v.abs() < 1.18e-38), "premise");
            let p = Precision::new(6.0, 0.0);
            let t = pack(&x, 32, 4, fmt, p);
            let q = quantized(fmt, &x, 32, 4, p);
            for (i, (u, qv)) in t.unpack().iter().zip(q.iter()).enumerate() {
                assert_eq!(u.to_bits(), qv.to_bits(), "{} elem {i}: {u} vs {qv}", fmt.name());
            }
        }
    }

    #[test]
    fn fp8_denormal_binade_round_trips() {
        // 0.0139 quantizes to 7 * 2^-9, BELOW 2^e_min: these grid values
        // use exponent code 0 with no hidden bit. An encoding that treats
        // ec=0 as plain zero silently flushes them (caught by the numpy
        // mirror of this layout before the Rust side ever compiled).
        let mut x = vec![0.013_914_669f32, -0.011_533_062, 0.007_812_5, 1.0];
        x.resize(32, 0.0);
        let p = Precision::new(8.0, 0.0);
        let t = pack(&x, 16, 2, FormatKind::Fp8, p);
        let q = quantized(FormatKind::Fp8, &x, 16, 2, p);
        assert!(q[0] != 0.0 && q[0] < pow2(-6), "premise: denormal grid value, got {}", q[0]);
        for (i, (u, qv)) in t.unpack().iter().zip(q.iter()).enumerate() {
            assert_eq!(u.to_bits(), qv.to_bits(), "elem {i}: {u} vs {qv}");
        }
    }

    #[test]
    fn fixed_point_round_trips_modulo_negative_zero() {
        let x = rand_tensor(7 * 9 + 5, 3, 1.0); // partial trailing group
        let p = Precision::new(8.0, 4.0);
        let t = pack(&x, 17, 4, FormatKind::Int, p);
        let q = quantized(FormatKind::Int, &x, 17, 4, p);
        for (i, (u, qv)) in t.unpack().iter().zip(q.iter()).enumerate() {
            let ok = u.to_bits() == qv.to_bits() || (*u == 0.0 && *qv == 0.0);
            assert!(ok, "elem {i}: {u} vs {qv}");
        }
    }

    #[test]
    fn storage_matches_sizing_oracle() {
        let cases = [
            (FormatKind::MxInt, Precision::new(7.0, 0.0), 64, 64),
            (FormatKind::MxInt, Precision::new(4.0, 0.0), 16, 6),
            (FormatKind::Bmf, Precision::new(5.0, 0.0), 32, 4),
            (FormatKind::Bl, Precision::new(7.0, 0.0), 16, 2),
            (FormatKind::Int, Precision::new(8.0, 3.0), 13, 5),
            (FormatKind::Fp8, Precision::new(8.0, 0.0), 9, 9),
            (FormatKind::Fp32, Precision::new(32.0, 0.0), 5, 7),
        ];
        for (fmt, p, rows, cols) in cases {
            let x = rand_tensor(rows * cols, 9, 1.0);
            let t = pack(&x, rows, cols, fmt, p);
            assert_eq!(
                t.storage_bits(),
                packed_bits_for(fmt, p, &[rows, cols]),
                "{} {rows}x{cols}",
                fmt.name()
            );
        }
    }

    #[test]
    fn mxint8_measured_bits_equal_analytic_on_tiling_shapes() {
        // 8-bit elements pack without padding: measured == Eq. (1).
        let p = Precision::new(7.0, 0.0);
        let bits = packed_bits_for(FormatKind::MxInt, p, &[64, 64]);
        assert_eq!(bits as f64, 64.0 * 64.0 * p.average_bitwidth(FormatKind::MxInt));
    }

    #[test]
    fn bmf_and_bl_measured_bits_exceed_analytic() {
        // The guard bit (BMF) and zero code (BL) are real storage the
        // analytic Eq. (1) does not count — module docs.
        for (fmt, p) in [
            (FormatKind::Bmf, Precision::new(5.0, 0.0)),
            (FormatKind::Bl, Precision::new(7.0, 0.0)),
        ] {
            let measured = packed_bits_for(fmt, p, &[64, 64]) as f64;
            let analytic = 64.0 * 64.0 * p.average_bitwidth(fmt);
            assert!(measured > analytic, "{}: {measured} vs {analytic}", fmt.name());
        }
    }

    #[test]
    fn odd_elem_widths_pad_each_block_to_a_word() {
        // m=4 -> 5-bit elements -> 160 bits -> 3 words, 32 padding bits.
        let lay = ElemLayout::new(FormatKind::MxInt, Precision::new(4.0, 0.0));
        assert_eq!(lay.elem_bits, 5);
        assert_eq!(lay.words_per_group(GROUP_ELEMS), 3);
        assert_eq!(lay.padding_bits_per_group(), 32);
    }

    #[test]
    fn partial_blocks_price_as_full_blocks() {
        let p = Precision::new(7.0, 0.0);
        assert_eq!(
            packed_bits_for(FormatKind::MxInt, p, &[17, 3]),
            packed_bits_for(FormatKind::MxInt, p, &[32, 4]),
        );
    }

    #[test]
    fn zero_element_tensor_costs_nothing() {
        assert_eq!(packed_bits_for(FormatKind::MxInt, Precision::new(7.0, 0.0), &[0, 64]), 0);
    }

    #[test]
    fn nan_precision_resolves_to_quantizer_floor() {
        // NaN knobs must not poison sizing (hw::memory robustness).
        let lay = ElemLayout::new(FormatKind::MxInt, Precision::new(f32::NAN, 0.0));
        assert_eq!(lay.knob, 1);
        assert!(packed_bits_for(FormatKind::MxInt, Precision::new(f32::NAN, 0.0), &[16, 2]) > 0);
    }

    #[test]
    fn fields_reproduce_decoded_values_exactly() {
        for (fmt, p) in [
            (FormatKind::MxInt, Precision::new(6.0, 0.0)),
            (FormatKind::Bmf, Precision::new(4.0, 0.0)),
            (FormatKind::Bl, Precision::new(5.0, 0.0)),
            (FormatKind::Int, Precision::new(9.0, 5.0)),
            (FormatKind::Fp8, Precision::new(8.0, 0.0)),
            (FormatKind::Fp32, Precision::new(32.0, 0.0)),
        ] {
            let x = rand_tensor(32 * 4, 21, 2.0);
            let t = pack(&x, 32, 4, fmt, p);
            for r in 0..32 {
                for c in 0..4 {
                    let v = t.get(r, c) as f64;
                    let (mant, exp) = t.fields_at(r, c);
                    let rebuilt = mant as f64 * crate::packed::kernels::pow2_f64(exp);
                    assert_eq!(rebuilt, v, "{} ({r},{c}): {mant}*2^{exp} vs {v}", fmt.name());
                }
            }
        }
    }
}
