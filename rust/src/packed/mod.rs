//! Bit-packed MX tensor storage and compute — the layer between the
//! format library ([`crate::formats`]) and the hardware model
//! ([`crate::hw`]).
//!
//! Everything in [`crate::formats`] is *fake* quantization: f32 values
//! snapped onto a format's representable grid. This module stores those
//! grids the way the paper's hardware does (§4.1): a shared 8-bit
//! exponent per (16, 2) block plus bit-packed per-element mantissa words,
//! and computes on the packed representation directly with the integer
//! mantissa MAC datapath of §4 (exponent alignment, widened accumulator).
//!
//! Two contracts anchor the whole layer, both enforced by tests:
//!
//!  1. **Round trip** ([`layout`]): `unpack(pack(x))` is bit-identical to
//!     the fake-quantized `formats::*_quantize(x)` output for all five
//!     formats, including signed zeros and subnormal-heavy blocks. (One
//!     documented exception: fixed point stores two's-complement
//!     integers, so the grid's `-0.0` canonicalizes to `+0.0`.)
//!  2. **Datapath agreement** ([`kernels`]): the packed integer
//!     dot-product/GEMM reproduces the f64-over-f32 float reference
//!     *exactly* for MXInt (and fixed point), and within a documented
//!     ULP bound for BMF / BL / minifloat — which makes `kernels` the
//!     golden software reference for the emitted SystemVerilog operators
//!     ([`crate::emit::templates`] sizes its accumulators from
//!     [`kernels::mxint_acc_bits`]) and the simulator's cost inputs.
//!
//! [`layout::packed_bits_for`] is the measured-storage oracle:
//! `hw::memory` prices parameter tensors with it (shared-exponent
//! amortization and word-alignment padding included) instead of the
//! idealized analytic bit count of Eq. (1), and `mase pack` dumps the
//! same numbers per tensor.

//!
//! [`artifact`] makes the packed representation durable: the `.mxa`
//! content-addressed container round-trips `PackedTensor`s to disk
//! byte-for-byte, so warm sessions (`--weights model.mxa`) load weights
//! with zero re-quantize and zero re-pack.

pub mod artifact;
pub mod kernels;
pub mod layout;

pub use artifact::{
    fnv1a, source_hash, ArtifactReader, ArtifactTensor, ArtifactWeights, ArtifactWriter,
    TensorDesc,
};
pub use kernels::{kernel_tally, mxint_acc_bits, packed_dot, packed_gemm, KernelTally};
pub use layout::{pack, packed_bits_for, ElemLayout, PackedTensor};
