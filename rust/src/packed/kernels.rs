//! Integer-datapath kernels over packed tensors — the software mirror of
//! the paper's §4 hardware dot-product (Fig. 3): integer mantissa
//! multiply-accumulate, exponent alignment shifts, one widened
//! accumulator per block, one floating-point accumulate per block flush.
//!
//! ## Accumulation order is part of the contract
//!
//! Hardware fixes the reduction order: integer MACs run exactly inside
//! one 32-element group (a (16, 2) block for block formats, a flat
//! 32-run otherwise — for GEMM, a 2-element k-segment, the widest run on
//! which both operands' shared exponents are structurally constant), and
//! the per-group partial is accumulated in floating point. The float
//! reference functions in this module ([`dot_f64_blocked`],
//! [`dot_f64_grouped`], [`gemm_f64_segmented`]) implement the *same*
//! order in plain f64 arithmetic over the fake-quantized f32 values, so
//! the agreement tests can assert:
//!
//!  * **MXInt and fixed point: exact equality.** Within a group every
//!    product is an integer multiple of one common power of two and the
//!    exact partial stays below 2^53, so the f64 reference accumulates
//!    the group exactly — and the integer datapath computes the same
//!    partial by construction. Both then perform the identical sequence
//!    of f64 adds across groups.
//!  * **BMF / FP8 / BL: documented ULP bound.** Per-element exponents
//!    vary inside a group; the aligner shifts products to the group's
//!    minimum exponent (span bounded by the format: <= 2*(2^eb - 1) for
//!    BMF, <= 28 for FP8). Whenever the span exceeds
//!    [`MAX_ALIGN_SHIFT`] (BL with wide element exponents), the kernel
//!    falls back to exact per-term f64 adds. Either way each group
//!    introduces at most one f64 rounding versus the element-order sum,
//!    so `|packed - reference| <= n * 2^-50 * sum|a_i * b_i|` — the
//!    bound the agreement tests assert.
//!
//! These kernels are the golden reference for the emitted SystemVerilog:
//! `emit::templates::mxint_dot_product` sizes its accumulator with
//! [`mxint_acc_bits`], and the cross-check tests assert the emitted
//! widths cover the worst case this datapath can produce.

use super::layout::{PackedTensor, GROUP_ELEMS};
use crate::formats::BLOCK_SHAPE;
use std::sync::atomic::{AtomicU64, Ordering};

// Process-global dispatch tallies (PR 8 observability): one relaxed
// atomic per kernel entry point, incremented on every call from any
// thread. Monotonic for the life of the process — consumers take
// before/after snapshots ([`kernel_tally`]) at single-threaded
// orchestration points and record the [`KernelTally::delta`], never the
// absolute values, so concurrent unrelated work only ever inflates
// *other* snapshots' windows, not a recorded delta's meaning.
static DOT_CALLS: AtomicU64 = AtomicU64::new(0);
static GEMM_TILED_CALLS: AtomicU64 = AtomicU64::new(0);
static GEMV_TALL_CALLS: AtomicU64 = AtomicU64::new(0);
static WEIGHT_PACK_CALLS: AtomicU64 = AtomicU64::new(0);

/// Record one *weight* tensor pack (interpreter parameter/embedding
/// packing — never per-matmul activation packing). The `.mxa` artifact
/// loader's "zero re-pack" contract is asserted on this counter: a warm
/// `--weights model.mxa` session must leave it untouched.
pub fn note_weight_pack() {
    WEIGHT_PACK_CALLS.fetch_add(1, Ordering::Relaxed);
}

/// Snapshot of the process-global kernel-dispatch counters: how many
/// times each packed entry point has run since process start. The
/// GEMM/GEMV split makes the decode fast-path dispatch rule
/// ([`packed_gemm`]'s `rows <= GEMV_TILE_ROWS` test) observable in
/// traces.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct KernelTally {
    /// [`packed_dot`] invocations.
    pub dot: u64,
    /// General tiled-GEMM path invocations.
    pub gemm_tiled: u64,
    /// Decode-shape GEMV fast-path invocations.
    pub gemv_tall: u64,
    /// Weight/embedding tensor packs ([`note_weight_pack`]) — zero on a
    /// warm artifact-backed session.
    pub weight_packs: u64,
}

impl KernelTally {
    /// Counter movement between an `earlier` snapshot and this one.
    pub fn delta(&self, earlier: &KernelTally) -> KernelTally {
        KernelTally {
            dot: self.dot.saturating_sub(earlier.dot),
            gemm_tiled: self.gemm_tiled.saturating_sub(earlier.gemm_tiled),
            gemv_tall: self.gemv_tall.saturating_sub(earlier.gemv_tall),
            weight_packs: self.weight_packs.saturating_sub(earlier.weight_packs),
        }
    }

    /// Fold this (delta) tally into a PR 8 trace registry under `path`.
    pub fn record_to(&self, rec: &crate::obs::Registry, path: &str) {
        if !rec.is_enabled() {
            return;
        }
        rec.counter(path, "packed_dot", self.dot);
        rec.counter(path, "packed_gemm_tiled", self.gemm_tiled);
        rec.counter(path, "packed_gemv_tall", self.gemv_tall);
        rec.counter(path, "weight_packs", self.weight_packs);
    }
}

/// Read the process-global dispatch counters (relaxed loads).
pub fn kernel_tally() -> KernelTally {
    KernelTally {
        dot: DOT_CALLS.load(Ordering::Relaxed),
        gemm_tiled: GEMM_TILED_CALLS.load(Ordering::Relaxed),
        gemv_tall: GEMV_TALL_CALLS.load(Ordering::Relaxed),
        weight_packs: WEIGHT_PACK_CALLS.load(Ordering::Relaxed),
    }
}

/// Widest exponent-alignment shift the integer datapath performs (the
/// hardware aligner width). Wider spans fall back to per-term f64 adds.
pub const MAX_ALIGN_SHIFT: i32 = 63;

/// Signed accumulator width sufficient for one 32-element MXInt block
/// dot-product at `m` mantissa bits: products reach (2^m - 1)^2 and 32
/// of them sum below 2^(2m + 5), so 2(m + 1) + log2(32) - 1 = 2m + 6
/// bits always hold the exact result. The emitted SystemVerilog operator
/// uses this width for its `ACC_W` parameter.
pub fn mxint_acc_bits(m: u32) -> u32 {
    2 * (m + 1) + (GROUP_ELEMS as u32).ilog2() - 1
}

/// Exact 2^e as f64 (e in [-1074, 1023]; subnormals included).
pub fn pow2_f64(e: i32) -> f64 {
    debug_assert!((-1074..=1023).contains(&e));
    if e >= -1022 {
        f64::from_bits(((e + 1023) as u64) << 52)
    } else {
        f64::from_bits(1u64 << (e + 1074))
    }
}

/// Flush one group of (mantissa-product, exponent) pairs into the f64
/// accumulator: align to the group's minimum exponent, integer-MAC in a
/// widened accumulator, one f64 accumulate. Falls back to per-term f64
/// adds when the alignment span exceeds [`MAX_ALIGN_SHIFT`].
fn flush_group(total: &mut f64, prods: &mut Vec<(i64, i32)>) {
    if prods.is_empty() {
        return;
    }
    let emin = prods.iter().map(|&(_, e)| e).min().unwrap();
    let emax = prods.iter().map(|&(_, e)| e).max().unwrap();
    if emax - emin <= MAX_ALIGN_SHIFT {
        let mut acc: i128 = 0;
        for &(m, e) in prods.iter() {
            acc += (m as i128) << (e - emin);
        }
        if acc != 0 {
            *total += acc as f64 * pow2_f64(emin);
        }
    } else {
        for &(m, e) in prods.iter() {
            *total += m as f64 * pow2_f64(e);
        }
    }
    prods.clear();
}

fn push_product(
    a: &PackedTensor,
    b: &PackedTensor,
    r: usize,
    c: usize,
    prods: &mut Vec<(i64, i32)>,
) {
    let (ma, ea) = a.fields_at(r, c);
    let (mb, eb) = b.fields_at(r, c);
    if ma != 0 && mb != 0 {
        prods.push((ma * mb, ea + eb));
    }
}

/// Dot product of two identically-shaped packed tensors, computed
/// directly on the packed representation (no f32 materialization).
/// Traversal/accumulation order per the module docs: (16, 2) blocks when
/// either operand is a block format, flat 32-groups otherwise.
pub fn packed_dot(a: &PackedTensor, b: &PackedTensor) -> f64 {
    DOT_CALLS.fetch_add(1, Ordering::Relaxed);
    assert_eq!((a.rows, a.cols), (b.rows, b.cols), "dot operands must share a shape");
    let mut total = 0.0f64;
    let mut prods: Vec<(i64, i32)> = Vec::with_capacity(GROUP_ELEMS);
    if a.layout.fmt.is_block_format() || b.layout.fmt.is_block_format() {
        let (br, bc) = BLOCK_SHAPE;
        assert!(a.rows % br == 0 && a.cols % bc == 0, "block formats need tiling shapes");
        for rb in 0..a.rows / br {
            for cb in 0..a.cols / bc {
                for r in 0..br {
                    for c in 0..bc {
                        push_product(a, b, rb * br + r, cb * bc + c, &mut prods);
                    }
                }
                flush_group(&mut total, &mut prods);
            }
        }
    } else {
        for i in 0..a.rows * a.cols {
            push_product(a, b, i / a.cols, i % a.cols, &mut prods);
            if i % GROUP_ELEMS == GROUP_ELEMS - 1 {
                flush_group(&mut total, &mut prods);
            }
        }
        flush_group(&mut total, &mut prods);
    }
    total
}

/// Width of a GEMM k-segment: a (16, 2) block of the left operand spans
/// 2 elements along k, a block of the right operand spans 16, so 2 is
/// the widest run on which both shared exponents are structurally
/// constant.
pub const GEMM_SEG: usize = BLOCK_SHAPE.1;

/// Row-count threshold for the decode GEMV path: at most one 16-row
/// output tile (autoregressive decode steps multiply a `[group, k]`
/// activation — seq-len-1 per sequence — against every weight).
pub const GEMV_TILE_ROWS: usize = 16;

/// Tiled GEMM `C[M,N] = A[M,K] * B[K,N]` computed directly on packed
/// data: per output element, integer MACs over 2-wide k-segments with
/// exponent alignment, one f64 accumulate per segment, final result
/// rounded to f32 (the hardware's FP32 output cast).
///
/// Shapes with at most [`GEMV_TILE_ROWS`] rows — the seq-len-1 GEMV
/// shape every KV-cached decode step produces — take
/// [`packed_gemv_tall`], which pre-extracts A's fields once and walks B
/// column-major so each packed B field is decoded once per output column
/// instead of once per (row, column) pair. Per output element both paths
/// push the same products into the same [`flush_group`] calls in the
/// same k order, so the results are **bitwise identical** (asserted by
/// `gemv_path_matches_tiled_path_bitwise` below and mirrored in
/// `scripts/verify_packed_math.py` C9).
pub fn packed_gemm(a: &PackedTensor, b: &PackedTensor) -> Vec<f32> {
    if a.rows <= GEMV_TILE_ROWS {
        packed_gemv_tall(a, b)
    } else {
        packed_gemm_tiled(a, b)
    }
}

/// The general 16x16-output-tile loop (mirrors the streaming tile loop).
fn packed_gemm_tiled(a: &PackedTensor, b: &PackedTensor) -> Vec<f32> {
    GEMM_TILED_CALLS.fetch_add(1, Ordering::Relaxed);
    assert_eq!(a.cols, b.rows, "inner dimensions must agree");
    let (m, k, n) = (a.rows, a.cols, b.cols);
    const TILE: usize = 16;
    let mut out = vec![0.0f32; m * n];
    let mut prods: Vec<(i64, i32)> = Vec::with_capacity(GEMM_SEG);
    for i0 in (0..m).step_by(TILE) {
        for j0 in (0..n).step_by(TILE) {
            for i in i0..(i0 + TILE).min(m) {
                for j in j0..(j0 + TILE).min(n) {
                    let mut total = 0.0f64;
                    let mut kk = 0;
                    while kk < k {
                        let seg_end = (kk + GEMM_SEG).min(k);
                        for t in kk..seg_end {
                            let (ma, ea) = a.fields_at(i, t);
                            let (mb, eb) = b.fields_at(t, j);
                            if ma != 0 && mb != 0 {
                                prods.push((ma * mb, ea + eb));
                            }
                        }
                        flush_group(&mut total, &mut prods);
                        kk = seg_end;
                    }
                    out[i * n + j] = total as f32;
                }
            }
        }
    }
    out
}

/// Decode-shape GEMV path (`m <= GEMV_TILE_ROWS`): A's packed fields are
/// extracted once up front, and B is walked column-major so each
/// k-segment of a B column is decoded once and reused across all A rows.
/// Per output element the same nonzero products reach the same
/// [`flush_group`] calls in the same k order as in the general tiled
/// loop, so the two paths are bitwise identical (see [`packed_gemm`]).
pub fn packed_gemv_tall(a: &PackedTensor, b: &PackedTensor) -> Vec<f32> {
    GEMV_TALL_CALLS.fetch_add(1, Ordering::Relaxed);
    assert_eq!(a.cols, b.rows, "inner dimensions must agree");
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let af: Vec<(i64, i32)> = (0..m * k).map(|i| a.fields_at(i / k, i % k)).collect();
    let mut out = vec![0.0f32; m * n];
    let mut acc = vec![0.0f64; m];
    let mut bf: Vec<(i64, i32)> = Vec::with_capacity(GEMM_SEG);
    let mut prods: Vec<(i64, i32)> = Vec::with_capacity(GEMM_SEG);
    for j in 0..n {
        acc.iter_mut().for_each(|v| *v = 0.0);
        let mut kk = 0;
        while kk < k {
            let seg_end = (kk + GEMM_SEG).min(k);
            bf.clear();
            for t in kk..seg_end {
                bf.push(b.fields_at(t, j));
            }
            for (i, total) in acc.iter_mut().enumerate() {
                for (t, &(mb, eb)) in (kk..seg_end).zip(bf.iter()) {
                    let (ma, ea) = af[i * k + t];
                    if ma != 0 && mb != 0 {
                        prods.push((ma * mb, ea + eb));
                    }
                }
                flush_group(total, &mut prods);
            }
            kk = seg_end;
        }
        for i in 0..m {
            out[i * n + j] = acc[i] as f32;
        }
    }
    out
}

/// Float half of the golden pair for [`packed_dot`] over block formats:
/// f64 partial per (16, 2) block of the fake-quantized f32 tensors, in
/// the quantizers' block order.
pub fn dot_f64_blocked(qa: &[f32], qb: &[f32], rows: usize, cols: usize) -> f64 {
    let (br, bc) = BLOCK_SHAPE;
    assert!(rows % br == 0 && cols % bc == 0);
    assert_eq!(qa.len(), rows * cols);
    assert_eq!(qa.len(), qb.len());
    let mut total = 0.0f64;
    crate::formats::for_each_block(rows, cols, |start| {
        let mut partial = 0.0f64;
        for r in 0..br {
            for c in 0..bc {
                let i = start + r * cols + c;
                partial += qa[i] as f64 * qb[i] as f64;
            }
        }
        total += partial;
    });
    total
}

/// Float half of the golden pair for [`packed_dot`] over element-wise
/// formats: f64 partial per flat 32-element group.
pub fn dot_f64_grouped(qa: &[f32], qb: &[f32]) -> f64 {
    assert_eq!(qa.len(), qb.len());
    let mut total = 0.0f64;
    for (ca, cb) in qa.chunks(GROUP_ELEMS).zip(qb.chunks(GROUP_ELEMS)) {
        let mut partial = 0.0f64;
        for (x, y) in ca.iter().zip(cb.iter()) {
            partial += *x as f64 * *y as f64;
        }
        total += partial;
    }
    total
}

/// Float half of the golden pair for [`packed_gemm`]: f64 partial per
/// 2-wide k-segment over the fake-quantized f32 operands, rounded to f32
/// like the hardware output cast.
pub fn gemm_f64_segmented(qa: &[f32], qb: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(qa.len(), m * k);
    assert_eq!(qb.len(), k * n);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut total = 0.0f64;
            let mut kk = 0;
            while kk < k {
                let seg_end = (kk + GEMM_SEG).min(k);
                let mut partial = 0.0f64;
                for t in kk..seg_end {
                    partial += qa[i * k + t] as f64 * qb[t * n + j] as f64;
                }
                total += partial;
                kk = seg_end;
            }
            out[i * n + j] = total as f32;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::{quantize_2d, FormatKind, Precision};
    use crate::packed::layout::pack;
    use crate::util::rng::Rng;

    fn rand_tensor(n: usize, seed: u64, scale: f64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| (rng.normal() * scale) as f32).collect()
    }

    #[test]
    fn pow2_f64_exact_across_range() {
        for e in [-1022, -300, -149, -1, 0, 1, 52, 1023] {
            assert_eq!(pow2_f64(e), 2f64.powi(e), "e={e}");
        }
        // subnormal tail, pinned by bit pattern (powi is not reliable here)
        assert_eq!(pow2_f64(-1074), f64::from_bits(1));
        assert_eq!(pow2_f64(-1073), f64::from_bits(2));
    }

    #[test]
    fn acc_bits_cover_worst_case_block() {
        for m in 1..=24u32 {
            let worst = 32u128 * ((1u128 << m) - 1).pow(2);
            let acc = mxint_acc_bits(m);
            assert!(worst <= (1u128 << (acc - 1)) - 1, "m={m}: {worst} needs more than {acc} bits");
        }
    }

    #[test]
    fn mxint_dot_equals_float_reference_exactly() {
        for (seed, ma, mb) in [(1u64, 7.0f32, 7.0f32), (2, 7.0, 4.0), (3, 3.0, 10.0)] {
            let (rows, cols) = (32, 8);
            let x = rand_tensor(rows * cols, seed, [1.0, 1e3, 1e-3][seed as usize % 3]);
            let y = rand_tensor(rows * cols, seed + 100, 1.0);
            let pa = pack(&x, rows, cols, FormatKind::MxInt, Precision::new(ma, 0.0));
            let pb = pack(&y, rows, cols, FormatKind::MxInt, Precision::new(mb, 0.0));
            let (mut qx, mut qy) = (x.clone(), y.clone());
            quantize_2d(FormatKind::MxInt, &mut qx, rows, cols, Precision::new(ma, 0.0));
            quantize_2d(FormatKind::MxInt, &mut qy, rows, cols, Precision::new(mb, 0.0));
            let packed = packed_dot(&pa, &pb);
            let reference = dot_f64_blocked(&qx, &qy, rows, cols);
            assert_eq!(packed, reference, "seed {seed}: {packed} vs {reference}");
        }
    }

    #[test]
    fn int_dot_equals_float_reference_exactly() {
        let (rows, cols) = (11, 7); // deliberately not a multiple of 32
        let x = rand_tensor(rows * cols, 5, 2.0);
        let y = rand_tensor(rows * cols, 6, 2.0);
        let p = Precision::new(8.0, 4.0);
        let pa = pack(&x, rows, cols, FormatKind::Int, p);
        let pb = pack(&y, rows, cols, FormatKind::Int, p);
        let (mut qx, mut qy) = (x.clone(), y.clone());
        quantize_2d(FormatKind::Int, &mut qx, rows, cols, p);
        quantize_2d(FormatKind::Int, &mut qy, rows, cols, p);
        assert_eq!(packed_dot(&pa, &pb), dot_f64_grouped(&qx, &qy));
    }

    #[test]
    fn zero_tensors_dot_to_zero() {
        let x = vec![0.0f32; 64];
        let pa = pack(&x, 32, 2, FormatKind::MxInt, Precision::new(5.0, 0.0));
        assert_eq!(packed_dot(&pa, &pa), 0.0);
    }

    #[test]
    fn mxint_gemm_equals_segmented_reference_exactly() {
        let (m, k, n) = (32, 32, 16);
        let x = rand_tensor(m * k, 9, 1.0);
        let y = rand_tensor(k * n, 10, 1.0);
        let (pa, pb) = (
            pack(&x, m, k, FormatKind::MxInt, Precision::new(7.0, 0.0)),
            pack(&y, k, n, FormatKind::MxInt, Precision::new(4.0, 0.0)),
        );
        let (mut qx, mut qy) = (x.clone(), y.clone());
        quantize_2d(FormatKind::MxInt, &mut qx, m, k, Precision::new(7.0, 0.0));
        quantize_2d(FormatKind::MxInt, &mut qy, k, n, Precision::new(4.0, 0.0));
        let packed = packed_gemm(&pa, &pb);
        let reference = gemm_f64_segmented(&qx, &qy, m, k, n);
        for (i, (p, r)) in packed.iter().zip(reference.iter()).enumerate() {
            assert_eq!(p.to_bits(), r.to_bits(), "C[{i}]: {p} vs {r}");
        }
    }

    #[test]
    fn gemv_path_matches_tiled_path_bitwise() {
        // m = 1 is the per-sequence decode GEMV; m = 16 is a full decode
        // group (and the largest shape the fast path accepts).
        for (m, seed) in [(1usize, 21u64), (16, 22)] {
            let (k, n) = (32, 48);
            let x = rand_tensor(m * k, seed, 1.0);
            let y = rand_tensor(k * n, seed + 50, 1.0);
            let (fmt, p) = if m == 1 {
                (FormatKind::Int, Precision::new(8.0, 4.0)) // element-wise: 1 row packs
            } else {
                (FormatKind::MxInt, Precision::new(7.0, 0.0))
            };
            let pa = pack(&x, m, k, fmt, p);
            let pb = pack(&y, k, n, FormatKind::MxInt, Precision::new(4.0, 0.0));
            let fast = packed_gemv_tall(&pa, &pb);
            let slow = packed_gemm_tiled(&pa, &pb);
            for (i, (f, s)) in fast.iter().zip(slow.iter()).enumerate() {
                assert_eq!(f.to_bits(), s.to_bits(), "m={m} C[{i}]: {f} vs {s}");
            }
        }
    }

    #[test]
    fn dispatch_tally_counts_each_entry_point() {
        // Unit tests share the process with every other test thread, so
        // assert window deltas with >=, never exact equality (the exact
        // accounting lives in tests/trace_determinism.rs behind a lock).
        let x = rand_tensor(32 * 32, 31, 1.0);
        let p = Precision::new(7.0, 0.0);
        let pa = pack(&x, 32, 32, FormatKind::MxInt, p);
        let before = kernel_tally();
        packed_dot(&pa, &pa);
        packed_gemm(&pa, &pa); // 32 rows > GEMV_TILE_ROWS -> tiled
        let one = pack(&x[..32], 1, 32, FormatKind::Int, Precision::new(8.0, 4.0));
        packed_gemm(&one, &pa); // 1 row -> gemv_tall
        let d = kernel_tally().delta(&before);
        assert!(d.dot >= 1, "{d:?}");
        assert!(d.gemm_tiled >= 1, "{d:?}");
        assert!(d.gemv_tall >= 1, "{d:?}");
        // record_to folds the three counters under the given path
        let reg = crate::obs::Registry::new();
        d.record_to(&reg, "kernels");
        assert_eq!(reg.counter_total("kernels", "packed_dot"), d.dot);
        assert_eq!(reg.counter_total("kernels", "packed_gemm_tiled"), d.gemm_tiled);
        assert_eq!(reg.counter_total("kernels", "packed_gemv_tall"), d.gemv_tall);
    }

    #[test]
    fn bl_wide_exponents_take_the_fallback_path_correctly() {
        // eb = 7 gives 127 exponent levels per operand: alignment spans
        // exceed MAX_ALIGN_SHIFT and the kernel must fall back without
        // losing more than the documented bound.
        let (rows, cols) = (32, 4);
        let x = rand_tensor(rows * cols, 13, 1.0);
        let y: Vec<f32> = rand_tensor(rows * cols, 14, 1.0)
            .iter()
            .enumerate()
            .map(|(i, v)| if i % 3 == 0 { v * 1e-30 } else { *v })
            .collect();
        let p = Precision::new(7.0, 0.0);
        let pa = pack(&x, rows, cols, FormatKind::Bl, p);
        let pb = pack(&y, rows, cols, FormatKind::Bl, p);
        let (mut qx, mut qy) = (x.clone(), y.clone());
        quantize_2d(FormatKind::Bl, &mut qx, rows, cols, p);
        quantize_2d(FormatKind::Bl, &mut qy, rows, cols, p);
        let packed = packed_dot(&pa, &pb);
        let reference = dot_f64_blocked(&qx, &qy, rows, cols);
        let gross: f64 =
            qx.iter().zip(qy.iter()).map(|(a, b)| (*a as f64 * *b as f64).abs()).sum();
        let bound = (qx.len() as f64) * 2f64.powi(-50) * gross;
        assert!((packed - reference).abs() <= bound, "{packed} vs {reference} (bound {bound})");
    }
}
